//! Expression DSL — typed scalar expressions over table columns.
//!
//! The paper positions Cylon under SQL-like layers ("SQL interfaces are
//! developed on top of these to enhance usability", §I). This module is
//! that seam: a small expression tree that evaluates vectorized over a
//! table, powering predicate pushdown into [`super::select`] and
//! computed columns for Project-with-derivation.
//!
//! ```
//! use rylon::ops::expr::Expr;
//! use rylon::io::generator::paper_table;
//! let t = paper_table(100, 1.0, 7);
//! // c1 + c2 > 1.0 && c0 % 2 == 0
//! let pred = Expr::col(1).add(Expr::col(2)).gt(Expr::lit_f64(1.0))
//!     .and(Expr::col(0).modulo(Expr::lit_i64(2)).eq(Expr::lit_i64(0)));
//! let filtered = rylon::ops::expr::filter(&t, &pred).unwrap();
//! assert!(filtered.num_rows() < t.num_rows());
//! ```
//!
//! Utf8 columns participate in comparisons (`Eq`/`Ne`/`Lt`/.../`IsNull`
//! against [`Expr::lit_str`] or other Utf8 columns, lexicographic byte
//! order) but not arithmetic. Null semantics are uniform across types:
//! a comparison touching a null cell is null, and nulls collapse to
//! `false` at [`filter`] time (SQL three-valued logic).
//!
//! The planner ([`crate::plan`]) manipulates expressions symbolically:
//! [`Expr::columns_referenced`] reports the input columns a predicate
//! needs, [`Expr::map_columns`] rewrites column indices when a
//! predicate sinks below a projection, and [`Expr::infer_type`]
//! type-checks an expression against a schema without evaluating it
//! (mirroring [`Expr::eval`]'s promotion rules exactly).

use crate::error::{Error, Result};
use crate::table::{take::filter_table, Array, DataType, Schema, Table};

/// A vectorized scalar expression.
#[derive(Debug, Clone)]
pub enum Expr {
    /// Column reference by index.
    Col(usize),
    LitI64(i64),
    LitF64(f64),
    LitBool(bool),
    LitStr(String),
    Add(Box<Expr>, Box<Expr>),
    Sub(Box<Expr>, Box<Expr>),
    Mul(Box<Expr>, Box<Expr>),
    Div(Box<Expr>, Box<Expr>),
    Mod(Box<Expr>, Box<Expr>),
    Eq(Box<Expr>, Box<Expr>),
    Ne(Box<Expr>, Box<Expr>),
    Lt(Box<Expr>, Box<Expr>),
    Le(Box<Expr>, Box<Expr>),
    Gt(Box<Expr>, Box<Expr>),
    Ge(Box<Expr>, Box<Expr>),
    And(Box<Expr>, Box<Expr>),
    Or(Box<Expr>, Box<Expr>),
    Not(Box<Expr>),
    /// Null test on a column expression.
    IsNull(Box<Expr>),
}

/// Evaluation result: a concrete column of values with validity.
/// Numeric ops null-propagate; comparisons on null are null (SQL
/// three-valued logic collapsed to "null = false" at filter time).
#[derive(Debug, Clone)]
pub enum Value {
    I64(Vec<i64>, Vec<bool>),
    F64(Vec<f64>, Vec<bool>),
    Bool(Vec<bool>, Vec<bool>),
    Str(Vec<String>, Vec<bool>),
}

impl Value {
    pub fn len(&self) -> usize {
        match self {
            Value::I64(v, _) => v.len(),
            Value::F64(v, _) => v.len(),
            Value::Bool(v, _) => v.len(),
            Value::Str(v, _) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn validity(&self) -> &[bool] {
        match self {
            Value::I64(_, m) | Value::F64(_, m) | Value::Bool(_, m) | Value::Str(_, m) => m,
        }
    }

    /// Materialize as a table column.
    pub fn into_array(self) -> Array {
        match self {
            Value::I64(v, m) => {
                if m.iter().all(|&x| x) {
                    Array::from_i64(v)
                } else {
                    Array::from_i64_opts(
                        v.into_iter().zip(m).map(|(x, ok)| ok.then_some(x)).collect(),
                    )
                }
            }
            Value::F64(v, m) => {
                if m.iter().all(|&x| x) {
                    Array::from_f64(v)
                } else {
                    Array::from_f64_opts(
                        v.into_iter().zip(m).map(|(x, ok)| ok.then_some(x)).collect(),
                    )
                }
            }
            Value::Bool(v, m) => {
                if m.iter().all(|&x| x) {
                    Array::from_bools(v)
                } else {
                    // null bool -> false with validity; Array supports opts
                    // only via builder; encode through builder:
                    let mut b = crate::table::builder::ArrayBuilder::new(
                        crate::table::DataType::Bool,
                    );
                    for (x, ok) in v.into_iter().zip(m) {
                        if ok {
                            b.push_bool(x).expect("bool builder");
                        } else {
                            b.push_null();
                        }
                    }
                    b.finish()
                }
            }
            Value::Str(v, m) => {
                let mut b =
                    crate::table::builder::ArrayBuilder::new(crate::table::DataType::Utf8);
                for (x, ok) in v.into_iter().zip(m) {
                    if ok {
                        b.push_str(&x).expect("utf8 builder");
                    } else {
                        b.push_null();
                    }
                }
                b.finish()
            }
        }
    }
}

/// Promote (i64, f64, bool) to f64 for mixed arithmetic. Callers guard
/// against `Value::Str` before promoting.
fn as_f64(v: &Value) -> (Vec<f64>, Vec<bool>) {
    match v {
        Value::I64(x, m) => (x.iter().map(|&a| a as f64).collect(), m.clone()),
        Value::F64(x, m) => (x.clone(), m.clone()),
        Value::Bool(x, m) => (x.iter().map(|&a| a as u8 as f64).collect(), m.clone()),
        Value::Str(..) => unreachable!("utf8 operands rejected before promotion"),
    }
}

fn zip_validity(a: &[bool], b: &[bool]) -> Vec<bool> {
    a.iter().zip(b).map(|(&x, &y)| x && y).collect()
}

macro_rules! arith {
    ($a:expr, $b:expr, $op:tt, $name:literal) => {{
        let (l, r) = ($a, $b);
        match (&l, &r) {
            (Value::Str(..), _) | (_, Value::Str(..)) => {
                Err(Error::schema(format!("{} over utf8 operands", $name)))
            }
            (Value::I64(x, mx), Value::I64(y, my)) => {
                if $name == "div" || $name == "mod" {
                    // guard zero divisors -> null
                    let mut m = zip_validity(mx, my);
                    let v: Vec<i64> = x
                        .iter()
                        .zip(y)
                        .enumerate()
                        .map(|(i, (&a, &b))| {
                            if b == 0 {
                                m[i] = false;
                                0
                            } else if $name == "div" {
                                a.wrapping_div(b)
                            } else {
                                a.wrapping_rem(b)
                            }
                        })
                        .collect();
                    Ok(Value::I64(v, m))
                } else {
                    let v = x.iter().zip(y).map(|(&a, &b)| a $op b).collect();
                    Ok(Value::I64(v, zip_validity(mx, my)))
                }
            }
            _ => {
                let (x, mx) = as_f64(&l);
                let (y, my) = as_f64(&r);
                if $name == "mod" {
                    let v = x.iter().zip(&y).map(|(&a, &b)| a % b).collect();
                    Ok(Value::F64(v, zip_validity(&mx, &my)))
                } else {
                    let v = x.iter().zip(&y).map(|(&a, &b)| a $op b).collect();
                    Ok(Value::F64(v, zip_validity(&mx, &my)))
                }
            }
        }
    }};
}

macro_rules! compare {
    ($a:expr, $b:expr, $op:tt) => {{
        let (l, r) = ($a, $b);
        match (&l, &r) {
            (Value::I64(x, mx), Value::I64(y, my)) => {
                let v = x.iter().zip(y).map(|(&a, &b)| a $op b).collect();
                Ok(Value::Bool(v, zip_validity(mx, my)))
            }
            // Utf8: lexicographic byte order, only against Utf8.
            (Value::Str(x, mx), Value::Str(y, my)) => {
                let v = x.iter().zip(y).map(|(a, b)| a $op b).collect();
                Ok(Value::Bool(v, zip_validity(mx, my)))
            }
            (Value::Str(..), _) | (_, Value::Str(..)) => {
                Err(Error::schema("comparison of utf8 with non-utf8 operand"))
            }
            _ => {
                let (x, mx) = as_f64(&l);
                let (y, my) = as_f64(&r);
                let v = x.iter().zip(&y).map(|(&a, &b)| a $op b).collect();
                Ok(Value::Bool(v, zip_validity(&mx, &my)))
            }
        }
    }};
}

/// A string comparison operand borrowed straight from its storage —
/// the filter hot path's alternative to materializing `Value::Str`
/// (one owned `String` per row for a column, one clone per row for a
/// literal).
enum StrOperand<'t> {
    Col(&'t crate::table::Utf8Array),
    Lit(&'t str),
}

impl<'t> StrOperand<'t> {
    #[inline]
    fn value(&self, row: usize) -> &'t str {
        match self {
            StrOperand::Col(a) => a.value(row),
            StrOperand::Lit(s) => s,
        }
    }

    #[inline]
    fn is_valid(&self, row: usize) -> bool {
        match self {
            StrOperand::Col(a) => a.is_valid(row),
            StrOperand::Lit(_) => true,
        }
    }
}

/// `Some` only when `e` evaluates to Utf8 rows borrowable without
/// copies: an in-range Utf8 column reference or a string literal.
/// Everything else (other types, out-of-range columns, compound
/// expressions) returns `None` so the generic path surfaces exactly
/// the errors and values it always has.
fn str_operand<'t>(e: &'t Expr, t: &'t Table) -> Option<StrOperand<'t>> {
    match e {
        Expr::Col(i) if *i < t.num_columns() => match t.column(*i).as_ref() {
            Array::Utf8(a) => Some(StrOperand::Col(a)),
            _ => None,
        },
        Expr::LitStr(s) => Some(StrOperand::Lit(s)),
        _ => None,
    }
}

/// Borrowed Utf8 comparison: bit-identical to evaluating both sides to
/// `Value::Str` and comparing (null cells compare as `""` then get
/// masked by validity — same as the materialized path), minus the
/// per-row allocations. Yields `None` when either side is not a
/// borrowable string operand.
macro_rules! str_compare {
    ($a:expr, $b:expr, $t:expr, $op:tt) => {{
        match (str_operand($a, $t), str_operand($b, $t)) {
            (Some(l), Some(r)) => {
                let n = $t.num_rows();
                let mut v = Vec::with_capacity(n);
                let mut m = Vec::with_capacity(n);
                for row in 0..n {
                    v.push(l.value(row) $op r.value(row));
                    m.push(l.is_valid(row) && r.is_valid(row));
                }
                Some(Value::Bool(v, m))
            }
            _ => None,
        }
    }};
}

impl Expr {
    // -- constructors ---------------------------------------------------
    pub fn col(i: usize) -> Expr {
        Expr::Col(i)
    }
    pub fn lit_i64(v: i64) -> Expr {
        Expr::LitI64(v)
    }
    pub fn lit_f64(v: f64) -> Expr {
        Expr::LitF64(v)
    }
    pub fn lit_bool(v: bool) -> Expr {
        Expr::LitBool(v)
    }
    pub fn lit_str(v: impl Into<String>) -> Expr {
        Expr::LitStr(v.into())
    }

    // -- combinators ----------------------------------------------------
    pub fn add(self, o: Expr) -> Expr {
        Expr::Add(self.into(), o.into())
    }
    pub fn sub(self, o: Expr) -> Expr {
        Expr::Sub(self.into(), o.into())
    }
    pub fn mul(self, o: Expr) -> Expr {
        Expr::Mul(self.into(), o.into())
    }
    pub fn div(self, o: Expr) -> Expr {
        Expr::Div(self.into(), o.into())
    }
    pub fn modulo(self, o: Expr) -> Expr {
        Expr::Mod(self.into(), o.into())
    }
    pub fn eq(self, o: Expr) -> Expr {
        Expr::Eq(self.into(), o.into())
    }
    pub fn ne(self, o: Expr) -> Expr {
        Expr::Ne(self.into(), o.into())
    }
    pub fn lt(self, o: Expr) -> Expr {
        Expr::Lt(self.into(), o.into())
    }
    pub fn le(self, o: Expr) -> Expr {
        Expr::Le(self.into(), o.into())
    }
    pub fn gt(self, o: Expr) -> Expr {
        Expr::Gt(self.into(), o.into())
    }
    pub fn ge(self, o: Expr) -> Expr {
        Expr::Ge(self.into(), o.into())
    }
    pub fn and(self, o: Expr) -> Expr {
        Expr::And(self.into(), o.into())
    }
    pub fn or(self, o: Expr) -> Expr {
        Expr::Or(self.into(), o.into())
    }
    #[allow(clippy::should_implement_trait)]
    pub fn not(self) -> Expr {
        Expr::Not(self.into())
    }
    pub fn is_null(self) -> Expr {
        Expr::IsNull(self.into())
    }

    /// Evaluate over all rows of `t`.
    pub fn eval(&self, t: &Table) -> Result<Value> {
        let n = t.num_rows();
        match self {
            Expr::Col(i) => {
                if *i >= t.num_columns() {
                    return Err(Error::invalid(format!("expr column {i} out of range")));
                }
                let col = t.column(*i);
                let validity: Vec<bool> = (0..n).map(|r| col.is_valid(r)).collect();
                Ok(match col.as_ref() {
                    Array::Int64(a) => Value::I64(a.values().to_vec(), validity),
                    Array::Float64(a) => Value::F64(a.values().to_vec(), validity),
                    Array::Bool(a) => Value::Bool(a.values().to_vec(), validity),
                    Array::Utf8(a) => {
                        Value::Str((0..n).map(|r| a.value(r).to_string()).collect(), validity)
                    }
                })
            }
            Expr::LitI64(v) => Ok(Value::I64(vec![*v; n], vec![true; n])),
            Expr::LitF64(v) => Ok(Value::F64(vec![*v; n], vec![true; n])),
            Expr::LitBool(v) => Ok(Value::Bool(vec![*v; n], vec![true; n])),
            Expr::LitStr(v) => Ok(Value::Str(vec![v.clone(); n], vec![true; n])),
            Expr::Add(a, b) => arith!(a.eval(t)?, b.eval(t)?, +, "add"),
            Expr::Sub(a, b) => arith!(a.eval(t)?, b.eval(t)?, -, "sub"),
            Expr::Mul(a, b) => arith!(a.eval(t)?, b.eval(t)?, *, "mul"),
            Expr::Div(a, b) => arith!(a.eval(t)?, b.eval(t)?, /, "div"),
            Expr::Mod(a, b) => arith!(a.eval(t)?, b.eval(t)?, %, "mod"),
            Expr::Eq(a, b) => match str_compare!(a, b, t, ==) {
                Some(v) => Ok(v),
                None => compare!(a.eval(t)?, b.eval(t)?, ==),
            },
            Expr::Ne(a, b) => match str_compare!(a, b, t, !=) {
                Some(v) => Ok(v),
                None => compare!(a.eval(t)?, b.eval(t)?, !=),
            },
            Expr::Lt(a, b) => match str_compare!(a, b, t, <) {
                Some(v) => Ok(v),
                None => compare!(a.eval(t)?, b.eval(t)?, <),
            },
            Expr::Le(a, b) => match str_compare!(a, b, t, <=) {
                Some(v) => Ok(v),
                None => compare!(a.eval(t)?, b.eval(t)?, <=),
            },
            Expr::Gt(a, b) => match str_compare!(a, b, t, >) {
                Some(v) => Ok(v),
                None => compare!(a.eval(t)?, b.eval(t)?, >),
            },
            Expr::Ge(a, b) => match str_compare!(a, b, t, >=) {
                Some(v) => Ok(v),
                None => compare!(a.eval(t)?, b.eval(t)?, >=),
            },
            Expr::And(a, b) => {
                let (x, y) = (a.eval(t)?, b.eval(t)?);
                match (&x, &y) {
                    (Value::Bool(l, ml), Value::Bool(r, mr)) => Ok(Value::Bool(
                        l.iter().zip(r).map(|(&a, &b)| a && b).collect(),
                        zip_validity(ml, mr),
                    )),
                    _ => Err(Error::schema("AND over non-bool operands")),
                }
            }
            Expr::Or(a, b) => {
                let (x, y) = (a.eval(t)?, b.eval(t)?);
                match (&x, &y) {
                    (Value::Bool(l, ml), Value::Bool(r, mr)) => Ok(Value::Bool(
                        l.iter().zip(r).map(|(&a, &b)| a || b).collect(),
                        zip_validity(ml, mr),
                    )),
                    _ => Err(Error::schema("OR over non-bool operands")),
                }
            }
            Expr::Not(a) => match a.eval(t)? {
                Value::Bool(v, m) => Ok(Value::Bool(v.into_iter().map(|b| !b).collect(), m)),
                _ => Err(Error::schema("NOT over non-bool operand")),
            },
            Expr::IsNull(a) => {
                // Borrowed Utf8 fast path: the mask only needs the
                // validity bitmap, so a Utf8 column never materializes
                // its strings here (the generic path below would copy
                // every row into an owned `String` just to drop it).
                if let Some(op) = str_operand(a, t) {
                    let mask: Vec<bool> = (0..n).map(|r| !op.is_valid(r)).collect();
                    return Ok(Value::Bool(mask, vec![true; n]));
                }
                let inner = a.eval(t)?;
                let mask: Vec<bool> = inner.validity().iter().map(|&ok| !ok).collect();
                Ok(Value::Bool(mask, vec![true; n]))
            }
        }
    }

    /// The two children of a binary node, one child of a unary node.
    fn children(&self) -> Vec<&Expr> {
        match self {
            Expr::Col(_)
            | Expr::LitI64(_)
            | Expr::LitF64(_)
            | Expr::LitBool(_)
            | Expr::LitStr(_) => vec![],
            Expr::Not(a) | Expr::IsNull(a) => vec![a.as_ref()],
            Expr::Add(a, b)
            | Expr::Sub(a, b)
            | Expr::Mul(a, b)
            | Expr::Div(a, b)
            | Expr::Mod(a, b)
            | Expr::Eq(a, b)
            | Expr::Ne(a, b)
            | Expr::Lt(a, b)
            | Expr::Le(a, b)
            | Expr::Gt(a, b)
            | Expr::Ge(a, b)
            | Expr::And(a, b)
            | Expr::Or(a, b) => vec![a.as_ref(), b.as_ref()],
        }
    }

    /// The set of input columns this expression reads, ascending and
    /// deduplicated. The planner uses it for projection pushdown (a
    /// predicate keeps exactly these columns alive below it) and to
    /// decide which join side a predicate can sink into.
    pub fn columns_referenced(&self) -> Vec<usize> {
        fn walk(e: &Expr, out: &mut Vec<usize>) {
            if let Expr::Col(i) = e {
                out.push(*i);
            }
            for c in e.children() {
                walk(c, out);
            }
        }
        let mut out = Vec::new();
        walk(self, &mut out);
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Rewrite every column reference through `f` — the remapping step
    /// when a predicate sinks below a Project (old output index → the
    /// projected-from input index) or into the right side of a join
    /// (subtract the left arity).
    pub fn map_columns(&self, f: &impl Fn(usize) -> usize) -> Expr {
        let m = |e: &Expr| Box::new(e.map_columns(f));
        match self {
            Expr::Col(i) => Expr::Col(f(*i)),
            Expr::LitI64(v) => Expr::LitI64(*v),
            Expr::LitF64(v) => Expr::LitF64(*v),
            Expr::LitBool(v) => Expr::LitBool(*v),
            Expr::LitStr(v) => Expr::LitStr(v.clone()),
            Expr::Add(a, b) => Expr::Add(m(a), m(b)),
            Expr::Sub(a, b) => Expr::Sub(m(a), m(b)),
            Expr::Mul(a, b) => Expr::Mul(m(a), m(b)),
            Expr::Div(a, b) => Expr::Div(m(a), m(b)),
            Expr::Mod(a, b) => Expr::Mod(m(a), m(b)),
            Expr::Eq(a, b) => Expr::Eq(m(a), m(b)),
            Expr::Ne(a, b) => Expr::Ne(m(a), m(b)),
            Expr::Lt(a, b) => Expr::Lt(m(a), m(b)),
            Expr::Le(a, b) => Expr::Le(m(a), m(b)),
            Expr::Gt(a, b) => Expr::Gt(m(a), m(b)),
            Expr::Ge(a, b) => Expr::Ge(m(a), m(b)),
            Expr::And(a, b) => Expr::And(m(a), m(b)),
            Expr::Or(a, b) => Expr::Or(m(a), m(b)),
            Expr::Not(a) => Expr::Not(m(a)),
            Expr::IsNull(a) => Expr::IsNull(m(a)),
        }
    }

    /// Static type of this expression over `schema`, mirroring
    /// [`Expr::eval`]'s promotion rules exactly: every expression that
    /// type-checks here evaluates without error on any table of this
    /// schema (runtime hazards like division by zero produce nulls,
    /// never errors). The optimizer validates every node with this
    /// before transforming a plan, so rewrites can't mask a type error
    /// the naive executor would have surfaced.
    pub fn infer_type(&self, schema: &Schema) -> Result<DataType> {
        let arith = |a: &Expr, b: &Expr, what: &str| -> Result<DataType> {
            match (a.infer_type(schema)?, b.infer_type(schema)?) {
                (DataType::Utf8, _) | (_, DataType::Utf8) => {
                    Err(Error::schema(format!("{what} over utf8 operands")))
                }
                (DataType::Int64, DataType::Int64) => Ok(DataType::Int64),
                _ => Ok(DataType::Float64),
            }
        };
        let compare = |a: &Expr, b: &Expr| -> Result<DataType> {
            match (a.infer_type(schema)?, b.infer_type(schema)?) {
                (DataType::Utf8, DataType::Utf8) => Ok(DataType::Bool),
                (DataType::Utf8, _) | (_, DataType::Utf8) => {
                    Err(Error::schema("comparison of utf8 with non-utf8 operand"))
                }
                _ => Ok(DataType::Bool),
            }
        };
        let boolean = |a: &Expr, b: &Expr, what: &str| -> Result<DataType> {
            match (a.infer_type(schema)?, b.infer_type(schema)?) {
                (DataType::Bool, DataType::Bool) => Ok(DataType::Bool),
                _ => Err(Error::schema(format!("{what} over non-bool operands"))),
            }
        };
        match self {
            Expr::Col(i) => {
                if *i >= schema.num_fields() {
                    return Err(Error::invalid(format!("expr column {i} out of range")));
                }
                Ok(schema.field(*i).data_type)
            }
            Expr::LitI64(_) => Ok(DataType::Int64),
            Expr::LitF64(_) => Ok(DataType::Float64),
            Expr::LitBool(_) => Ok(DataType::Bool),
            Expr::LitStr(_) => Ok(DataType::Utf8),
            Expr::Add(a, b) => arith(a, b, "add"),
            Expr::Sub(a, b) => arith(a, b, "sub"),
            Expr::Mul(a, b) => arith(a, b, "mul"),
            Expr::Div(a, b) => arith(a, b, "div"),
            Expr::Mod(a, b) => arith(a, b, "mod"),
            Expr::Eq(a, b)
            | Expr::Ne(a, b)
            | Expr::Lt(a, b)
            | Expr::Le(a, b)
            | Expr::Gt(a, b)
            | Expr::Ge(a, b) => compare(a, b),
            Expr::And(a, b) => boolean(a, b, "AND"),
            Expr::Or(a, b) => boolean(a, b, "OR"),
            Expr::Not(a) => match a.infer_type(schema)? {
                DataType::Bool => Ok(DataType::Bool),
                _ => Err(Error::schema("NOT over non-bool operand")),
            },
            Expr::IsNull(a) => {
                a.infer_type(schema)?;
                Ok(DataType::Bool)
            }
        }
    }
}

impl std::fmt::Display for Expr {
    /// Compact infix rendering used by plan explainers: `c0`, `(c1 +
    /// 0.5)`, `(c0 % 2 == 0)`.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let bin = |f: &mut std::fmt::Formatter<'_>, a: &Expr, op: &str, b: &Expr| {
            write!(f, "({a} {op} {b})")
        };
        match self {
            Expr::Col(i) => write!(f, "c{i}"),
            Expr::LitI64(v) => write!(f, "{v}"),
            Expr::LitF64(v) => write!(f, "{v:?}"),
            Expr::LitBool(v) => write!(f, "{v}"),
            Expr::LitStr(v) => write!(f, "{v:?}"),
            Expr::Add(a, b) => bin(f, a, "+", b),
            Expr::Sub(a, b) => bin(f, a, "-", b),
            Expr::Mul(a, b) => bin(f, a, "*", b),
            Expr::Div(a, b) => bin(f, a, "/", b),
            Expr::Mod(a, b) => bin(f, a, "%", b),
            Expr::Eq(a, b) => bin(f, a, "==", b),
            Expr::Ne(a, b) => bin(f, a, "!=", b),
            Expr::Lt(a, b) => bin(f, a, "<", b),
            Expr::Le(a, b) => bin(f, a, "<=", b),
            Expr::Gt(a, b) => bin(f, a, ">", b),
            Expr::Ge(a, b) => bin(f, a, ">=", b),
            Expr::And(a, b) => bin(f, a, "&&", b),
            Expr::Or(a, b) => bin(f, a, "||", b),
            Expr::Not(a) => write!(f, "!({a})"),
            Expr::IsNull(a) => write!(f, "is_null({a})"),
        }
    }
}

/// Filter rows where the predicate evaluates to (valid) true.
pub fn filter(t: &Table, pred: &Expr) -> Result<Table> {
    match pred.eval(t)? {
        Value::Bool(v, m) => {
            let mask: Vec<bool> = v.iter().zip(&m).map(|(&b, &ok)| b && ok).collect();
            filter_table(t, &mask)
        }
        _ => Err(Error::schema("filter predicate is not boolean")),
    }
}

/// Append a computed column `name = expr` (Project-with-derivation).
///
/// Utf8 sources take a borrowed path: a string column or literal is
/// pushed straight from its backing storage into the new column's
/// builder, skipping the `Value::Str` detour (one owned `String` per
/// row) that the generic eval path would take.
pub fn with_column(t: &Table, name: &str, expr: &Expr) -> Result<Table> {
    let array = if let Some(op) = str_operand(expr, t) {
        let mut b = crate::table::builder::ArrayBuilder::new(DataType::Utf8);
        for row in 0..t.num_rows() {
            if op.is_valid(row) {
                b.push_str(op.value(row))?;
            } else {
                b.push_null();
            }
        }
        b.finish()
    } else {
        expr.eval(t)?.into_array()
    };
    let mut fields = t.schema().fields().to_vec();
    fields.push(crate::table::Field::new(name, array.data_type()));
    let mut cols = t.columns().to_vec();
    cols.push(std::sync::Arc::new(array));
    Table::try_new(std::sync::Arc::new(crate::table::Schema::new(fields)), cols)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::table::Array;

    fn t() -> Table {
        Table::from_arrays(vec![
            ("i", Array::from_i64_opts(vec![Some(1), Some(2), None, Some(4)])),
            ("f", Array::from_f64(vec![0.5, 1.5, 2.5, 3.5])),
            ("b", Array::from_bools(vec![true, false, true, false])),
        ])
        .unwrap()
    }

    #[test]
    fn arithmetic_and_promotion() {
        // i + f promotes to f64
        let v = Expr::col(0).add(Expr::col(1)).eval(&t()).unwrap();
        match v {
            Value::F64(x, m) => {
                assert_eq!(x[0], 1.5);
                assert_eq!(x[3], 7.5);
                assert!(!m[2]); // null propagates
            }
            _ => panic!("expected f64"),
        }
    }

    #[test]
    fn integer_mod_and_div_by_zero() {
        let tz = Table::from_arrays(vec![
            ("a", Array::from_i64(vec![7, 8])),
            ("z", Array::from_i64(vec![2, 0])),
        ])
        .unwrap();
        let v = Expr::col(0).modulo(Expr::col(1)).eval(&tz).unwrap();
        match v {
            Value::I64(x, m) => {
                assert_eq!(x[0], 1);
                assert!(m[0]);
                assert!(!m[1]); // mod 0 -> null, not panic
            }
            _ => panic!("expected i64"),
        }
    }

    #[test]
    fn filter_with_three_valued_logic() {
        // i > 1: rows 1 (2>1) and 3 (4>1); row 2 null -> excluded
        let out = filter(&t(), &Expr::col(0).gt(Expr::lit_i64(1))).unwrap();
        assert_eq!(out.num_rows(), 2);
    }

    #[test]
    fn boolean_combinators() {
        let pred = Expr::col(2).or(Expr::col(1).lt(Expr::lit_f64(1.0)));
        let out = filter(&t(), &pred).unwrap();
        assert_eq!(out.num_rows(), 2); // rows 0 (b & f<1), 2 (b)
        let not_out = filter(&t(), &pred.clone().not()).unwrap();
        assert_eq!(out.num_rows() + not_out.num_rows(), 4);
    }

    #[test]
    fn is_null_predicate() {
        let out = filter(&t(), &Expr::col(0).is_null()).unwrap();
        assert_eq!(out.num_rows(), 1);
        let out2 = filter(&t(), &Expr::col(0).is_null().not()).unwrap();
        assert_eq!(out2.num_rows(), 3);
    }

    #[test]
    fn with_column_appends() {
        let out = with_column(&t(), "double_f", &Expr::col(1).mul(Expr::lit_f64(2.0))).unwrap();
        assert_eq!(out.num_columns(), 4);
        assert_eq!(out.schema().field(3).name, "double_f");
        assert_eq!(out.column(3).as_f64().unwrap().value(1), 3.0);
    }

    #[test]
    fn type_errors() {
        assert!(Expr::col(9).eval(&t()).is_err());
        assert!(Expr::col(0).and(Expr::col(1)).eval(&t()).is_err());
        assert!(filter(&t(), &Expr::col(0).add(Expr::col(1))).is_err());
        let s = Table::from_arrays(vec![("s", Array::from_strs(&["x"]))]).unwrap();
        // Utf8 compares but never does arithmetic or mixed comparison.
        assert!(Expr::col(0).add(Expr::lit_i64(1)).eval(&s).is_err());
        assert!(Expr::col(0).eq(Expr::lit_i64(1)).eval(&s).is_err());
        assert!(Expr::col(0).eq(Expr::lit_str("x")).eval(&s).is_ok());
    }

    fn st() -> Table {
        Table::from_arrays(vec![
            (
                "s",
                Array::Utf8(crate::table::column::Utf8Array::from_options(&[
                    Some("apple"),
                    Some("banana"),
                    None,
                    Some("cherry"),
                ])),
            ),
            ("k", Array::from_i64(vec![1, 2, 3, 4])),
        ])
        .unwrap()
    }

    #[test]
    fn utf8_comparisons_filter() {
        // equality against a literal; the null row is excluded
        let out = filter(&st(), &Expr::col(0).eq(Expr::lit_str("banana"))).unwrap();
        assert_eq!(out.num_rows(), 1);
        assert_eq!(out.column(1).as_i64().unwrap().value(0), 2);
        // lexicographic range
        let out = filter(&st(), &Expr::col(0).gt(Expr::lit_str("apple"))).unwrap();
        assert_eq!(out.num_rows(), 2); // banana, cherry (null row -> false)
        // ne keeps the other valid rows, drops the null row
        let out = filter(&st(), &Expr::col(0).ne(Expr::lit_str("apple"))).unwrap();
        assert_eq!(out.num_rows(), 2);
        // is_null works on utf8
        let out = filter(&st(), &Expr::col(0).is_null()).unwrap();
        assert_eq!(out.num_rows(), 1);
        assert_eq!(out.column(1).as_i64().unwrap().value(0), 3);
    }

    #[test]
    fn utf8_col_col_compare_borrows() {
        // Both operands ride the borrowed fast path; null on either
        // side masks the row exactly like the materialized path did.
        let t = Table::from_arrays(vec![
            (
                "a",
                Array::Utf8(crate::table::column::Utf8Array::from_options(&[
                    Some("x"),
                    Some("b"),
                    None,
                    Some("d"),
                ])),
            ),
            (
                "b",
                Array::Utf8(crate::table::column::Utf8Array::from_options(&[
                    Some("x"),
                    Some("c"),
                    Some("e"),
                    None,
                ])),
            ),
        ])
        .unwrap();
        let out = filter(&t, &Expr::col(0).eq(Expr::col(1))).unwrap();
        assert_eq!(out.num_rows(), 1); // only ("x","x"); null rows -> false
        let out = filter(&t, &Expr::col(0).lt(Expr::col(1))).unwrap();
        assert_eq!(out.num_rows(), 1); // "b" < "c"
        // literal-literal comparison is constant over all rows
        let out = filter(&t, &Expr::lit_str("a").lt(Expr::lit_str("b"))).unwrap();
        assert_eq!(out.num_rows(), 4);
    }

    #[test]
    fn utf8_with_column_materializes() {
        let out = with_column(&st(), "copy", &Expr::col(0)).unwrap();
        assert_eq!(out.num_columns(), 3);
        assert_eq!(out.column(2).as_utf8().unwrap().value(1), "banana");
        assert!(!out.column(2).is_valid(2));
    }

    #[test]
    fn utf8_null_heavy_borrowed_paths() {
        // Mostly-null Utf8 column: is_null, with_column, and literal
        // projection all ride the borrowed paths and must agree with
        // the validity bitmap exactly.
        let opts: Vec<Option<&str>> = (0..64)
            .map(|i| if i % 8 == 3 { Some(if i % 16 == 3 { "hit" } else { "" }) } else { None })
            .collect();
        let t = Table::from_arrays(vec![
            ("s", Array::Utf8(crate::table::column::Utf8Array::from_options(&opts))),
            ("k", Array::from_i64((0..64).collect())),
        ])
        .unwrap();
        let n_valid = opts.iter().filter(|o| o.is_some()).count();

        // IsNull never materializes the strings; count matches.
        let nulls = filter(&t, &Expr::col(0).is_null()).unwrap();
        assert_eq!(nulls.num_rows(), 64 - n_valid);
        let valid = filter(&t, &Expr::col(0).is_null().not()).unwrap();
        assert_eq!(valid.num_rows(), n_valid);

        // with_column copies the column through the borrowed builder:
        // values, empties, and nulls all survive round-trip.
        let out = with_column(&t, "copy", &Expr::col(0)).unwrap();
        let copy = out.column(2).as_utf8().unwrap();
        for (i, o) in opts.iter().enumerate() {
            match o {
                Some(s) => {
                    assert!(out.column(2).is_valid(i), "row {i} valid");
                    assert_eq!(copy.value(i), *s, "row {i} value");
                }
                None => assert!(!out.column(2).is_valid(i), "row {i} null"),
            }
        }

        // Literal projection: every row valid, every row the literal.
        let out = with_column(&t, "lit", &Expr::lit_str("z")).unwrap();
        let lit = out.column(2).as_utf8().unwrap();
        for i in 0..64 {
            assert!(out.column(2).is_valid(i));
            assert_eq!(lit.value(i), "z");
        }

        // Null-heavy comparison still masks to false on null rows.
        let eq = filter(&t, &Expr::col(0).eq(Expr::lit_str(""))).unwrap();
        let expect_empty = opts.iter().filter(|o| **o == Some("")).count();
        assert_eq!(eq.num_rows(), expect_empty);
    }

    #[test]
    fn columns_referenced_and_remap() {
        let e = Expr::col(3).add(Expr::col(1)).gt(Expr::lit_f64(0.0)).and(
            Expr::col(1).is_null().not(),
        );
        assert_eq!(e.columns_referenced(), vec![1, 3]);
        let shifted = e.map_columns(&|c| c + 10);
        assert_eq!(shifted.columns_referenced(), vec![11, 13]);
        // remapped expression evaluates identically on a shifted table
        let t = t();
        let wide = Table::from_arrays(vec![
            ("i", Array::from_i64_opts(vec![Some(1), Some(2), None, Some(4)])),
            ("f", Array::from_f64(vec![0.5, 1.5, 2.5, 3.5])),
        ])
        .unwrap();
        let e2 = Expr::col(0).gt(Expr::lit_i64(1));
        let r1 = filter(&t, &e2).unwrap();
        let r2 = filter(&wide, &e2.map_columns(&|c| c)).unwrap();
        assert_eq!(r1.num_rows(), r2.num_rows());
    }

    #[test]
    fn infer_type_mirrors_eval() {
        use crate::table::DataType;
        let schema = t().schema().as_ref().clone();
        let cases: Vec<(Expr, DataType)> = vec![
            (Expr::col(0), DataType::Int64),
            (Expr::col(0).add(Expr::lit_i64(1)), DataType::Int64),
            (Expr::col(0).add(Expr::col(1)), DataType::Float64),
            (Expr::col(2).mul(Expr::lit_f64(2.0)), DataType::Float64),
            (Expr::col(0).gt(Expr::lit_i64(0)), DataType::Bool),
            (Expr::col(2).and(Expr::lit_bool(true)), DataType::Bool),
            (Expr::col(1).is_null(), DataType::Bool),
        ];
        for (e, want) in cases {
            assert_eq!(e.infer_type(&schema).unwrap(), want, "{e}");
            // what infer says, eval produces
            let v = e.eval(&t()).unwrap();
            let got = match v {
                Value::I64(..) => DataType::Int64,
                Value::F64(..) => DataType::Float64,
                Value::Bool(..) => DataType::Bool,
                Value::Str(..) => DataType::Utf8,
            };
            assert_eq!(got, want, "{e}");
        }
        // errors match eval's errors
        assert!(Expr::col(9).infer_type(&schema).is_err());
        assert!(Expr::col(0).and(Expr::col(1)).infer_type(&schema).is_err());
        let ss = st().schema().as_ref().clone();
        assert!(Expr::col(0).add(Expr::lit_i64(1)).infer_type(&ss).is_err());
        assert!(Expr::col(0).eq(Expr::lit_i64(1)).infer_type(&ss).is_err());
        assert_eq!(
            Expr::col(0).lt(Expr::lit_str("m")).infer_type(&ss).unwrap(),
            crate::table::DataType::Bool
        );
    }

    #[test]
    fn display_is_compact_infix() {
        let e = Expr::col(0).modulo(Expr::lit_i64(2)).eq(Expr::lit_i64(0));
        assert_eq!(format!("{e}"), "((c0 % 2) == 0)");
        let s = Expr::col(1).eq(Expr::lit_str("x"));
        assert_eq!(format!("{s}"), "(c1 == \"x\")");
    }
}
