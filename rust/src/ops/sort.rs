//! Sort — order a table by one column (internal building block for
//! sort-join and the user-facing `Sort` local operator).
//!
//! Sorting is done on a permutation-index vector (pdqsort via
//! `sort_unstable_by`) and materialized with one columnar `take` per
//! column, so payload columns are moved once.

use crate::error::{Error, Result};
use crate::table::{take::take_table, Array, Table};
use std::cmp::Ordering;

/// Total-order comparison of two cells of one column. Nulls sort first;
/// floats use IEEE total order (NaN last among valids).
#[inline]
pub fn cmp_cells(a: &Array, i: usize, j: usize) -> Ordering {
    match (a.is_valid(i), a.is_valid(j)) {
        (false, false) => Ordering::Equal,
        (false, true) => Ordering::Less,
        (true, false) => Ordering::Greater,
        (true, true) => match a {
            Array::Int64(p) => p.value(i).cmp(&p.value(j)),
            Array::Float64(p) => p.value(i).total_cmp(&p.value(j)),
            Array::Utf8(s) => s.value(i).cmp(s.value(j)),
            Array::Bool(b) => b.value(i).cmp(&b.value(j)),
        },
    }
}

/// Compare cell `i` of column `a` against cell `j` of column `b`
/// (same type required) — used by sort-join's cross-table merge scan.
#[inline]
pub fn cmp_cells_across(a: &Array, i: usize, b: &Array, j: usize) -> Ordering {
    match (a.is_valid(i), b.is_valid(j)) {
        (false, false) => Ordering::Equal,
        (false, true) => Ordering::Less,
        (true, false) => Ordering::Greater,
        (true, true) => match (a, b) {
            (Array::Int64(x), Array::Int64(y)) => x.value(i).cmp(&y.value(j)),
            (Array::Float64(x), Array::Float64(y)) => x.value(i).total_cmp(&y.value(j)),
            (Array::Utf8(x), Array::Utf8(y)) => x.value(i).cmp(y.value(j)),
            (Array::Bool(x), Array::Bool(y)) => x.value(i).cmp(&y.value(j)),
            _ => panic!("cmp_cells_across on mismatched types"),
        },
    }
}

/// Ascending permutation of row indices ordering `t` by column `col`.
pub fn sort_indices(t: &Table, col: usize) -> Result<Vec<usize>> {
    if col >= t.num_columns() {
        return Err(Error::invalid(format!("sort column {col} out of range")));
    }
    let a = t.column(col).as_ref();
    let mut idx: Vec<usize> = (0..t.num_rows()).collect();
    // Typed fast path for the common int64 key column: sort by cached keys
    // instead of re-dereferencing through the enum per comparison.
    if let Array::Int64(p) = a {
        if p.null_count() == 0 {
            let vals = p.values();
            idx.sort_unstable_by_key(|&i| vals[i]);
            return Ok(idx);
        }
    }
    idx.sort_unstable_by(|&i, &j| cmp_cells(a, i, j));
    Ok(idx)
}

/// Materialized sort of a table by column `col`.
pub fn sort(t: &Table, col: usize) -> Result<Table> {
    let idx = sort_indices(t, col)?;
    Ok(take_table(t, &idx))
}

/// Check ascending order of `col` (testing / merge preconditions).
pub fn is_sorted(t: &Table, col: usize) -> bool {
    let a = t.column(col).as_ref();
    (1..t.num_rows()).all(|i| cmp_cells(a, i - 1, i) != Ordering::Greater)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::table::Array;

    #[test]
    fn sorts_ints_with_nulls_first() {
        let t = Table::from_arrays(vec![(
            "k",
            Array::from_i64_opts(vec![Some(3), None, Some(-1), Some(2)]),
        )])
        .unwrap();
        let s = sort(&t, 0).unwrap();
        let k = s.column(0).as_i64().unwrap();
        assert!(!k.is_valid(0));
        assert_eq!(k.get(1), Some(-1));
        assert_eq!(k.get(2), Some(2));
        assert_eq!(k.get(3), Some(3));
        assert!(is_sorted(&s, 0));
    }

    #[test]
    fn fast_path_matches_generic() {
        let vals: Vec<i64> = vec![5, 3, 3, 8, -2, 0, 5];
        let t = Table::from_arrays(vec![("k", Array::from_i64(vals.clone()))]).unwrap();
        let s = sort(&t, 0).unwrap();
        let mut expect = vals;
        expect.sort();
        assert_eq!(s.column(0).as_i64().unwrap().values(), &expect[..]);
    }

    #[test]
    fn sorts_floats_total_order() {
        let t = Table::from_arrays(vec![(
            "k",
            Array::from_f64(vec![f64::NAN, 1.0, -1.0, 0.0]),
        )])
        .unwrap();
        let s = sort(&t, 0).unwrap();
        let k = s.column(0).as_f64().unwrap();
        assert_eq!(k.value(0), -1.0);
        assert_eq!(k.value(1), 0.0);
        assert_eq!(k.value(2), 1.0);
        assert!(k.value(3).is_nan());
    }

    #[test]
    fn sorts_strings() {
        let t = Table::from_arrays(vec![("k", Array::from_strs(&["b", "", "aa", "a"]))]).unwrap();
        let s = sort(&t, 0).unwrap();
        let k = s.column(0).as_utf8().unwrap();
        assert_eq!(
            (0..4).map(|i| k.value(i)).collect::<Vec<_>>(),
            vec!["", "a", "aa", "b"]
        );
    }

    #[test]
    fn payload_moves_with_key() {
        let t = Table::from_arrays(vec![
            ("k", Array::from_i64(vec![2, 1])),
            ("v", Array::from_strs(&["two", "one"])),
        ])
        .unwrap();
        let s = sort(&t, 0).unwrap();
        assert_eq!(s.column(1).as_utf8().unwrap().value(0), "one");
    }

    #[test]
    fn out_of_range_column() {
        let t = Table::from_arrays(vec![("k", Array::from_i64(vec![1]))]).unwrap();
        assert!(sort(&t, 5).is_err());
    }

    #[test]
    fn empty_table_sorts() {
        let t = Table::from_arrays(vec![("k", Array::from_i64(vec![]))]).unwrap();
        assert_eq!(sort(&t, 0).unwrap().num_rows(), 0);
    }
}
