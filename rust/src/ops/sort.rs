//! Sort — the typed, morsel-parallel sort engine behind the local
//! `Sort` operator, sort-join, external sort, and distributed
//! sample-sort.
//!
//! # Typed sort keys
//!
//! The seed sorted through [`cmp_cells`], paying `Array`-enum dispatch
//! plus a validity branch on *every comparison*. The engine instead
//! resolves the key column's type **once**, at key-extraction time:
//!
//! * `Int64` → order-preserving `u64` ([`encode_i64`]: flip the sign
//!   bit);
//! * `Float64` → order-preserving `u64` ([`encode_f64`]: IEEE-754
//!   total-order bit twiddling, bit-compatible with `f64::total_cmp` —
//!   `-NaN < -∞ < … < -0.0 < +0.0 < … < +∞ < +NaN`);
//! * `Bool` → rank `u64` ([`encode_bool`]);
//! * `Utf8` → no fixed-width encoding; indices are compared through a
//!   typed `&str` comparator (UTF-8 byte order equals `char` order).
//!
//! Null rows never enter a comparison at all: validity is scanned 64
//! rows at a time ([`crate::table::bitmap::Bitmap::for_each_word_range`],
//! the same word-wise fast path the columnar hash kernels use) and null
//! rows are emitted **first**, in ascending row order — exactly where
//! `cmp_cells`'s null-first ordering would place them.
//!
//! # Determinism contract (stable ties)
//!
//! [`sort_indices`] orders by `(key, original row index)`: duplicate
//! keys keep their input order, so the output permutation is a pure
//! function of the input — bit-identical at every thread count, the
//! same contract the join/group-by engines pin in
//! `tests/prop_parallel.rs` (sort adds `tests/prop_sort.rs`). Once the
//! valid rows span more than one morsel ([`SORT_PAR_MIN_ROWS`]), fixed
//! 64Ki-row morsels are sorted concurrently and k-way-merged in morsel
//! order ([`super::parallel::merge_runs`]); at or below it the serial
//! path runs — both produce the unique `(key, row)`-ascending
//! permutation.
//!
//! ```
//! use rylon::ops::sort::sort;
//! use rylon::table::{Array, Table};
//!
//! // Duplicate keys keep their original relative order (stable ties):
//! let t = Table::from_arrays(vec![
//!     ("k", Array::from_i64(vec![2, 1, 2, 1])),
//!     ("v", Array::from_strs(&["a", "b", "c", "d"])),
//! ])
//! .unwrap();
//! let s = sort(&t, 0).unwrap();
//! let v = s.column(1).as_utf8().unwrap();
//! assert_eq!(
//!     (0..4).map(|i| v.value(i)).collect::<Vec<_>>(),
//!     vec!["b", "d", "a", "c"] // 1@row1, 1@row3, 2@row0, 2@row2
//! );
//! ```
//!
//! Sorting is done on a permutation-index vector and materialized with
//! one columnar `take` per column, so payload columns are moved once.

use super::parallel::{concat_chunks, map_morsels, merge_runs, parallelism, MORSEL_ROWS};
use crate::error::{Error, Result};
use crate::table::bitmap::{classify_word, WordKind};
use crate::table::column::{BoolArray, Float64Array, Int64Array, Utf8Array};
use crate::table::{take::take_table, Array, Table};
use std::cmp::Ordering;

/// Valid-row count above which `sort_indices` takes the morsel-parallel
/// path: the input must span **more than one** [`MORSEL_ROWS`] morsel,
/// because a single run would be a copy of the serial sort, not a
/// concurrency win. Purely a speed heuristic: both paths produce the
/// identical `(key, row)`-ascending permutation.
pub const SORT_PAR_MIN_ROWS: usize = MORSEL_ROWS;

/// Order-preserving `u64` encoding of an `i64` (flip the sign bit):
/// `a < b  ⇔  encode_i64(a) < encode_i64(b)`.
#[inline(always)]
pub fn encode_i64(v: i64) -> u64 {
    (v as u64) ^ (1u64 << 63)
}

/// Order-preserving `u64` encoding of an `f64` under IEEE-754 total
/// order (bit-compatible with `f64::total_cmp`): negative values flip
/// all bits, non-negative values flip the sign bit.
#[inline(always)]
pub fn encode_f64(v: f64) -> u64 {
    let b = v.to_bits();
    if b >> 63 == 1 {
        !b
    } else {
        b | (1u64 << 63)
    }
}

/// Rank encoding of a `bool` (`false < true`), leaving 0 free as a
/// null-first sentinel for consumers that need one.
#[inline(always)]
pub fn encode_bool(v: bool) -> u64 {
    v as u64 + 1
}

/// Total-order comparison of two cells of one column. Nulls sort first;
/// floats use IEEE total order (NaN last among valids).
///
/// This is the *reference* comparator — the typed engine below must
/// (and, property-tested, does) order exactly like it. Hot loops use
/// the typed paths; keep this for oracles and one-off comparisons.
#[inline]
pub fn cmp_cells(a: &Array, i: usize, j: usize) -> Ordering {
    match (a.is_valid(i), a.is_valid(j)) {
        (false, false) => Ordering::Equal,
        (false, true) => Ordering::Less,
        (true, false) => Ordering::Greater,
        (true, true) => match a {
            Array::Int64(p) => p.value(i).cmp(&p.value(j)),
            Array::Float64(p) => p.value(i).total_cmp(&p.value(j)),
            Array::Utf8(s) => s.value(i).cmp(s.value(j)),
            Array::Bool(b) => b.value(i).cmp(&b.value(j)),
        },
    }
}

/// Compare cell `i` of column `a` against cell `j` of column `b`
/// (same type required). Reference counterpart of [`KeyCol`] — merge
/// scans resolve the pair to typed keys once instead of dispatching
/// here per comparison.
#[inline]
pub fn cmp_cells_across(a: &Array, i: usize, b: &Array, j: usize) -> Ordering {
    match (a.is_valid(i), b.is_valid(j)) {
        (false, false) => Ordering::Equal,
        (false, true) => Ordering::Less,
        (true, false) => Ordering::Greater,
        (true, true) => match (a, b) {
            (Array::Int64(x), Array::Int64(y)) => x.value(i).cmp(&y.value(j)),
            (Array::Float64(x), Array::Float64(y)) => x.value(i).total_cmp(&y.value(j)),
            (Array::Utf8(x), Array::Utf8(y)) => x.value(i).cmp(y.value(j)),
            (Array::Bool(x), Array::Bool(y)) => x.value(i).cmp(&y.value(j)),
            _ => panic!("cmp_cells_across on mismatched types"),
        },
    }
}

/// Typed order access to one column: the `Array` enum is resolved to a
/// concrete `KeyCol` once, then the consumer's comparison loop is
/// monomorphized over it — primitive compares with no enum dispatch on
/// the hot path. Orders exactly like [`cmp_cells_across`].
pub trait KeyCol: Copy + Send + Sync {
    /// Row `i` is non-null.
    fn valid(&self, i: usize) -> bool;

    /// Compare two *valid* cells (`self[i]` vs `other[j]`).
    fn cmp_values(&self, i: usize, other: &Self, j: usize) -> Ordering;

    /// Null-aware comparison (nulls first, like [`cmp_cells`]).
    #[inline]
    fn cmp_full(&self, i: usize, other: &Self, j: usize) -> Ordering {
        match (self.valid(i), other.valid(j)) {
            (false, false) => Ordering::Equal,
            (false, true) => Ordering::Less,
            (true, false) => Ordering::Greater,
            (true, true) => self.cmp_values(i, other, j),
        }
    }
}

/// [`KeyCol`] over an `Int64` column.
#[derive(Clone, Copy)]
pub struct I64Key<'a>(pub &'a Int64Array);

/// [`KeyCol`] over a `Float64` column (IEEE total order).
#[derive(Clone, Copy)]
pub struct F64Key<'a>(pub &'a Float64Array);

/// [`KeyCol`] over a `Utf8` column.
#[derive(Clone, Copy)]
pub struct StrKey<'a>(pub &'a Utf8Array);

/// [`KeyCol`] over a `Bool` column.
#[derive(Clone, Copy)]
pub struct BoolKey<'a>(pub &'a BoolArray);

impl KeyCol for I64Key<'_> {
    #[inline]
    fn valid(&self, i: usize) -> bool {
        self.0.is_valid(i)
    }
    #[inline]
    fn cmp_values(&self, i: usize, other: &Self, j: usize) -> Ordering {
        self.0.value(i).cmp(&other.0.value(j))
    }
}

impl KeyCol for F64Key<'_> {
    #[inline]
    fn valid(&self, i: usize) -> bool {
        self.0.is_valid(i)
    }
    #[inline]
    fn cmp_values(&self, i: usize, other: &Self, j: usize) -> Ordering {
        self.0.value(i).total_cmp(&other.0.value(j))
    }
}

impl KeyCol for StrKey<'_> {
    #[inline]
    fn valid(&self, i: usize) -> bool {
        self.0.is_valid(i)
    }
    #[inline]
    fn cmp_values(&self, i: usize, other: &Self, j: usize) -> Ordering {
        self.0.value(i).cmp(other.0.value(j))
    }
}

impl KeyCol for BoolKey<'_> {
    #[inline]
    fn valid(&self, i: usize) -> bool {
        self.0.is_valid(i)
    }
    #[inline]
    fn cmp_values(&self, i: usize, other: &Self, j: usize) -> Ordering {
        self.0.value(i).cmp(&other.0.value(j))
    }
}

/// Split `a`'s row indices into (null rows, valid rows), both in
/// ascending row order, scanning validity 64 rows at a time.
fn split_null_first(a: &Array) -> (Vec<usize>, Vec<usize>) {
    let n = a.len();
    let Some(v) = a.validity() else {
        return (Vec::new(), (0..n).collect());
    };
    let nv = v.count_valid();
    let mut nulls = Vec::with_capacity(n - nv);
    let mut valids = Vec::with_capacity(nv);
    v.for_each_word_range(0..n, |lo, hi, bits| match classify_word(bits, hi - lo) {
        WordKind::Valid => valids.extend(lo..hi),
        WordKind::Null => nulls.extend(lo..hi),
        WordKind::Mixed => {
            for k in 0..(hi - lo) {
                if (bits >> k) & 1 == 1 {
                    valids.push(lo + k);
                } else {
                    nulls.push(lo + k);
                }
            }
        }
    });
    (nulls, valids)
}

/// One-pass typed key extraction: order-preserving `u64` keys for
/// every row (`None` for `Utf8`, which compares through [`StrKey`]).
/// Entries at null rows are never compared — the null split happens
/// before any comparison. Morsel-parallel; bit-identical at any
/// `threads`.
fn encode_keys(a: &Array, threads: usize) -> Option<Vec<u64>> {
    let n = a.len();
    match a {
        Array::Int64(p) => Some(concat_chunks(
            map_morsels(n, threads, |r| {
                p.values()[r].iter().map(|&v| encode_i64(v)).collect::<Vec<u64>>()
            }),
            n,
        )),
        Array::Float64(p) => Some(concat_chunks(
            map_morsels(n, threads, |r| {
                p.values()[r].iter().map(|&v| encode_f64(v)).collect::<Vec<u64>>()
            }),
            n,
        )),
        Array::Bool(b) => Some(concat_chunks(
            map_morsels(n, threads, |r| {
                b.values()[r].iter().map(|&v| encode_bool(v)).collect::<Vec<u64>>()
            }),
            n,
        )),
        Array::Utf8(_) => None,
    }
}

/// Sort the valid-row index vector by `cmp` (a total order — in
/// practice `(key, row)`). Serial at or below [`SORT_PAR_MIN_ROWS`]
/// (a single morsel); otherwise fixed 64Ki-row morsels sort
/// concurrently and merge in morsel order. Both paths yield the
/// identical permutation.
fn sort_valid_indices<F>(mut valids: Vec<usize>, threads: usize, cmp: F) -> Vec<usize>
where
    F: Fn(&usize, &usize) -> Ordering + Sync,
{
    // Serial when there is nothing to win: one thread requested, or
    // only a single morsel would exist (its "parallel" sort is the
    // serial sort plus a copy).
    if threads <= 1 || valids.len() <= SORT_PAR_MIN_ROWS {
        valids.sort_unstable_by(|a, b| cmp(a, b));
        return valids;
    }
    let runs: Vec<Vec<usize>> = map_morsels(valids.len(), threads, |r| {
        let mut run = valids[r].to_vec();
        run.sort_unstable_by(|a, b| cmp(a, b));
        run
    });
    merge_runs(runs, threads, |a, b| cmp(a, b) != Ordering::Greater)
}

/// Ascending permutation of row indices ordering `t` by column `col`:
/// nulls first (in row order), then valid rows by `(key, row)` —
/// duplicate keys keep their input order. Uses the process-default
/// thread budget; see [`sort_indices_par`].
pub fn sort_indices(t: &Table, col: usize) -> Result<Vec<usize>> {
    sort_indices_par(t, col, parallelism())
}

/// [`sort_indices`] with an explicit thread budget. The permutation is
/// bit-identical at every `threads` value.
pub fn sort_indices_par(t: &Table, col: usize, threads: usize) -> Result<Vec<usize>> {
    if col >= t.num_columns() {
        return Err(Error::invalid(format!("sort column {col} out of range")));
    }
    let a = t.column(col).as_ref();
    let (nulls, valids) = split_null_first(a);
    let sorted = match encode_keys(a, threads) {
        Some(keys) => sort_valid_indices(valids, threads, |&i, &j| {
            keys[i].cmp(&keys[j]).then(i.cmp(&j))
        }),
        None => {
            let s = a.as_utf8().expect("non-primitive sort keys are utf8");
            sort_valid_indices(valids, threads, |&i, &j| {
                s.value(i).cmp(s.value(j)).then(i.cmp(&j))
            })
        }
    };
    let mut out = nulls;
    out.extend(sorted);
    Ok(out)
}

/// Materialized sort of a table by column `col` (stable on duplicate
/// keys; process-default parallelism).
pub fn sort(t: &Table, col: usize) -> Result<Table> {
    sort_par(t, col, parallelism())
}

/// [`sort`] with an explicit thread budget; output is bit-identical at
/// every `threads` value.
pub fn sort_par(t: &Table, col: usize, threads: usize) -> Result<Table> {
    let idx = sort_indices_par(t, col, threads)?;
    Ok(take_table(t, &idx))
}

/// Check ascending order of `col` (testing / merge preconditions).
/// Typed: one enum resolution, primitive compares per row.
pub fn is_sorted(t: &Table, col: usize) -> bool {
    fn run<K: KeyCol>(k: K, n: usize) -> bool {
        (1..n).all(|i| k.cmp_full(i - 1, &k, i) != Ordering::Greater)
    }
    let n = t.num_rows();
    match t.column(col).as_ref() {
        Array::Int64(p) => run(I64Key(p), n),
        Array::Float64(p) => run(F64Key(p), n),
        Array::Utf8(s) => run(StrKey(s), n),
        Array::Bool(b) => run(BoolKey(b), n),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::table::Array;

    #[test]
    fn sorts_ints_with_nulls_first() {
        let t = Table::from_arrays(vec![(
            "k",
            Array::from_i64_opts(vec![Some(3), None, Some(-1), Some(2)]),
        )])
        .unwrap();
        let s = sort(&t, 0).unwrap();
        let k = s.column(0).as_i64().unwrap();
        assert!(!k.is_valid(0));
        assert_eq!(k.get(1), Some(-1));
        assert_eq!(k.get(2), Some(2));
        assert_eq!(k.get(3), Some(3));
        assert!(is_sorted(&s, 0));
    }

    #[test]
    fn fast_path_matches_generic() {
        let vals: Vec<i64> = vec![5, 3, 3, 8, -2, 0, 5];
        let t = Table::from_arrays(vec![("k", Array::from_i64(vals.clone()))]).unwrap();
        let s = sort(&t, 0).unwrap();
        let mut expect = vals;
        expect.sort();
        assert_eq!(s.column(0).as_i64().unwrap().values(), &expect[..]);
    }

    #[test]
    fn sorts_floats_total_order() {
        let t = Table::from_arrays(vec![(
            "k",
            Array::from_f64(vec![f64::NAN, 1.0, -1.0, 0.0]),
        )])
        .unwrap();
        let s = sort(&t, 0).unwrap();
        let k = s.column(0).as_f64().unwrap();
        assert_eq!(k.value(0), -1.0);
        assert_eq!(k.value(1), 0.0);
        assert_eq!(k.value(2), 1.0);
        assert!(k.value(3).is_nan());
    }

    #[test]
    fn sorts_strings() {
        let t = Table::from_arrays(vec![("k", Array::from_strs(&["b", "", "aa", "a"]))]).unwrap();
        let s = sort(&t, 0).unwrap();
        let k = s.column(0).as_utf8().unwrap();
        assert_eq!(
            (0..4).map(|i| k.value(i)).collect::<Vec<_>>(),
            vec!["", "a", "aa", "b"]
        );
    }

    #[test]
    fn sorts_bools_with_nulls_stably() {
        let t = Table::from_arrays(vec![
            (
                "k",
                Array::Bool(crate::table::column::BoolArray::from_options(vec![
                    Some(true),
                    None,
                    Some(false),
                    Some(true),
                    None,
                    Some(false),
                ])),
            ),
            ("row", Array::from_i64((0..6).collect())),
        ])
        .unwrap();
        for threads in [1usize, 2, 7] {
            let s = sort_par(&t, 0, threads).unwrap();
            // nulls (rows 1, 4), then false (2, 5), then true (0, 3) —
            // each block in original row order (stable ties).
            let r = s.column(1).as_i64().unwrap();
            assert_eq!(r.values(), &[1, 4, 2, 5, 0, 3], "threads={threads}");
            assert!(is_sorted(&s, 0));
        }
    }

    #[test]
    fn payload_moves_with_key() {
        let t = Table::from_arrays(vec![
            ("k", Array::from_i64(vec![2, 1])),
            ("v", Array::from_strs(&["two", "one"])),
        ])
        .unwrap();
        let s = sort(&t, 0).unwrap();
        assert_eq!(s.column(1).as_utf8().unwrap().value(0), "one");
    }

    #[test]
    fn out_of_range_column() {
        let t = Table::from_arrays(vec![("k", Array::from_i64(vec![1]))]).unwrap();
        assert!(sort(&t, 5).is_err());
    }

    #[test]
    fn empty_table_sorts() {
        let t = Table::from_arrays(vec![("k", Array::from_i64(vec![]))]).unwrap();
        assert_eq!(sort(&t, 0).unwrap().num_rows(), 0);
    }

    #[test]
    fn encodings_preserve_order() {
        let ints = [i64::MIN, -2, -1, 0, 1, 2, i64::MAX];
        for w in ints.windows(2) {
            assert!(encode_i64(w[0]) < encode_i64(w[1]), "{w:?}");
        }
        let neg_nan = f64::from_bits(f64::NAN.to_bits() | (1u64 << 63));
        let floats = [
            neg_nan,
            f64::NEG_INFINITY,
            -1.5,
            -0.0,
            0.0,
            1.5,
            f64::INFINITY,
            f64::NAN,
        ];
        for w in floats.windows(2) {
            assert!(encode_f64(w[0]) < encode_f64(w[1]), "{w:?}");
            assert_eq!(w[0].total_cmp(&w[1]), Ordering::Less, "{w:?}");
        }
        // Equal bit patterns encode equal.
        assert_eq!(encode_f64(1.5), encode_f64(1.5));
        assert!(encode_bool(false) < encode_bool(true));
        assert!(encode_bool(false) > 0, "0 stays free for a null sentinel");
    }

    #[test]
    fn stable_on_duplicate_keys() {
        // Payload records the original row; equal keys must keep it
        // ascending at every thread count.
        let keys: Vec<i64> = (0..500).map(|i| (i * 7) % 5).collect();
        let rows: Vec<i64> = (0..500).collect();
        let t = Table::from_arrays(vec![
            ("k", Array::from_i64(keys)),
            ("row", Array::from_i64(rows)),
        ])
        .unwrap();
        for threads in [1usize, 2, 7] {
            let s = sort_par(&t, 0, threads).unwrap();
            let k = s.column(0).as_i64().unwrap();
            let r = s.column(1).as_i64().unwrap();
            for i in 1..s.num_rows() {
                assert!(k.value(i - 1) <= k.value(i));
                if k.value(i - 1) == k.value(i) {
                    assert!(r.value(i - 1) < r.value(i), "unstable tie at {i}");
                }
            }
        }
    }

    #[test]
    fn typed_keycol_matches_cmp_cells_across() {
        let a = Array::from_f64_opts(vec![Some(1.0), None, Some(f64::NAN), Some(-0.0)]);
        let b = Array::from_f64_opts(vec![Some(0.0), Some(2.0), None, Some(f64::NAN)]);
        let (Array::Float64(x), Array::Float64(y)) = (&a, &b) else { unreachable!() };
        let (ka, kb) = (F64Key(x), F64Key(y));
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(
                    ka.cmp_full(i, &kb, j),
                    cmp_cells_across(&a, i, &b, j),
                    "({i},{j})"
                );
            }
        }
    }

    #[test]
    fn null_split_is_word_exact() {
        // Nulls at word boundaries: rows 0, 63, 64 and 127 null.
        let vals: Vec<Option<i64>> = (0..130)
            .map(|i| if [0, 63, 64, 127].contains(&i) { None } else { Some(i) })
            .collect();
        let t = Table::from_arrays(vec![("k", Array::from_i64_opts(vals))]).unwrap();
        let idx = sort_indices(&t, 0).unwrap();
        assert_eq!(&idx[..4], &[0, 63, 64, 127], "nulls first, row order");
        assert!(is_sorted(&sort(&t, 0).unwrap(), 0));
    }
}
