//! Join — combine two tables on a key column (§II-B3).
//!
//! Two algorithms, as in the paper:
//!
//! * **Hash join**: build a hash map on the smaller relation's key column,
//!   probe with the larger (the build/probe swap is why Table II's hash
//!   join beats sort join at scale).
//! * **Sort join**: sort both sides on the key (permutation indices only),
//!   then a linear merge scan with duplicate-block cross products.
//!
//! Both produce identical multisets of output rows for all four join
//! semantics (property-tested in `tests/prop_join.rs`).
//!
//! Null semantics: SQL-style — a null key never matches anything (not
//! even another null), but null-keyed rows still appear in outer results.

use super::hash::hash_cell;
use super::sort::cmp_cells_across;
use crate::error::{Error, Result};
use crate::table::{take::take_table_opt, Schema, Table};
use std::cmp::Ordering;
use std::sync::Arc;

/// The four join semantics of Table I.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JoinType {
    Inner,
    Left,
    Right,
    FullOuter,
}

/// Algorithm selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JoinAlgorithm {
    Hash,
    Sort,
}

/// Join configuration: semantics + key columns + algorithm
/// (the `cylon::join::config::JoinConfig` analog).
#[derive(Debug, Clone, Copy)]
pub struct JoinConfig {
    pub join_type: JoinType,
    pub left_col: usize,
    pub right_col: usize,
    pub algorithm: JoinAlgorithm,
}

impl JoinConfig {
    pub fn new(join_type: JoinType, left_col: usize, right_col: usize) -> Self {
        JoinConfig { join_type, left_col, right_col, algorithm: JoinAlgorithm::Hash }
    }

    pub fn inner(l: usize, r: usize) -> Self {
        Self::new(JoinType::Inner, l, r)
    }

    pub fn left(l: usize, r: usize) -> Self {
        Self::new(JoinType::Left, l, r)
    }

    pub fn right(l: usize, r: usize) -> Self {
        Self::new(JoinType::Right, l, r)
    }

    pub fn full_outer(l: usize, r: usize) -> Self {
        Self::new(JoinType::FullOuter, l, r)
    }

    pub fn with_algorithm(mut self, a: JoinAlgorithm) -> Self {
        self.algorithm = a;
        self
    }
}

/// Local join entry point.
pub fn join(left: &Table, right: &Table, cfg: &JoinConfig) -> Result<Table> {
    if cfg.left_col >= left.num_columns() || cfg.right_col >= right.num_columns() {
        return Err(Error::invalid("join column out of range"));
    }
    let lk = left.column(cfg.left_col).as_ref();
    let rk = right.column(cfg.right_col).as_ref();
    if lk.data_type() != rk.data_type() {
        return Err(Error::schema(format!(
            "join key types differ: {:?} vs {:?}",
            lk.data_type(),
            rk.data_type()
        )));
    }
    let (li, ri) = match cfg.algorithm {
        JoinAlgorithm::Hash => hash_join_indices(left, right, cfg),
        JoinAlgorithm::Sort => sort_join_indices(left, right, cfg),
    };
    materialize(left, right, &li, &ri)
}

/// Build the output table from matched index pairs (None = outer null).
fn materialize(
    left: &Table,
    right: &Table,
    li: &[Option<usize>],
    ri: &[Option<usize>],
) -> Result<Table> {
    debug_assert_eq!(li.len(), ri.len());
    let lt = take_table_opt(left, li);
    let rt = take_table_opt(right, ri);
    let schema = Arc::new(left.schema().join(right.schema()));
    let mut cols = Vec::with_capacity(lt.num_columns() + rt.num_columns());
    cols.extend(lt.columns().iter().cloned());
    cols.extend(rt.columns().iter().cloned());
    Table::try_new(schema, cols)
}

/// A flat chained hash table over row indices: `first[bucket]` heads a
/// linked list threaded through `next[row]`. One allocation each, no
/// per-bucket Vecs — ~2–3× faster to build than `HashMap<u32, Vec>` and
/// the probe walk is cache-linear in `next`.
pub(crate) struct ChainTable {
    mask: u32,
    first: Vec<u32>,
    next: Vec<u32>,
    hashes: Vec<u32>,
}

pub(crate) const CHAIN_END: u32 = u32::MAX;

impl ChainTable {
    /// Build over the valid rows of `key`.
    pub(crate) fn build(key: &crate::table::Array, rows: usize) -> ChainTable {
        let buckets = (rows.max(1) * 2).next_power_of_two();
        let mask = (buckets - 1) as u32;
        let mut first = vec![CHAIN_END; buckets];
        let mut next = vec![CHAIN_END; rows];
        let mut hashes = vec![0u32; rows];
        for i in 0..rows {
            if key.is_valid(i) {
                let h = hash_cell(key, i);
                hashes[i] = h;
                let b = (h & mask) as usize;
                next[i] = first[b];
                first[b] = i as u32;
            }
        }
        ChainTable { mask, first, next, hashes }
    }

    /// Iterate candidate build rows whose hash equals `h`.
    #[inline]
    pub(crate) fn candidates(&self, h: u32) -> ChainIter<'_> {
        ChainIter { table: self, cur: self.first[(h & self.mask) as usize], hash: h }
    }
}

pub(crate) struct ChainIter<'a> {
    table: &'a ChainTable,
    cur: u32,
    hash: u32,
}

impl Iterator for ChainIter<'_> {
    type Item = usize;

    #[inline]
    fn next(&mut self) -> Option<usize> {
        while self.cur != CHAIN_END {
            let i = self.cur as usize;
            self.cur = self.table.next[i];
            if self.table.hashes[i] == self.hash {
                return Some(i);
            }
        }
        None
    }
}

/// Hash join: build on the smaller side, probe with the larger.
fn hash_join_indices(
    left: &Table,
    right: &Table,
    cfg: &JoinConfig,
) -> (Vec<Option<usize>>, Vec<Option<usize>>) {
    // Swap so `build` is the smaller relation; remember orientation.
    let left_builds = left.num_rows() <= right.num_rows();
    let (build_t, build_col, probe_t, probe_col) = if left_builds {
        (left, cfg.left_col, right, cfg.right_col)
    } else {
        (right, cfg.right_col, left, cfg.left_col)
    };
    let bk = build_t.column(build_col).as_ref();
    let pk = probe_t.column(probe_col).as_ref();

    // Chained-index table; hash collisions resolved by key comparison.
    let map = ChainTable::build(bk, build_t.num_rows());

    let mut build_matched = vec![false; build_t.num_rows()];
    let mut bi: Vec<Option<usize>> = Vec::with_capacity(probe_t.num_rows());
    let mut pi: Vec<Option<usize>> = Vec::with_capacity(probe_t.num_rows());

    let probe_outer = match (cfg.join_type, left_builds) {
        (JoinType::Inner, _) => false,
        (JoinType::FullOuter, _) => true,
        (JoinType::Left, true) => false,  // left is build side
        (JoinType::Left, false) => true,  // left is probe side
        (JoinType::Right, true) => true,  // right is probe side
        (JoinType::Right, false) => false,
    };
    let build_outer = match (cfg.join_type, left_builds) {
        (JoinType::Inner, _) => false,
        (JoinType::FullOuter, _) => true,
        (JoinType::Left, true) => true,
        (JoinType::Left, false) => false,
        (JoinType::Right, true) => false,
        (JoinType::Right, false) => true,
    };

    for j in 0..probe_t.num_rows() {
        let mut matched = false;
        if pk.is_valid(j) {
            for i in map.candidates(hash_cell(pk, j)) {
                if cmp_cells_across(bk, i, pk, j) == Ordering::Equal {
                    bi.push(Some(i));
                    pi.push(Some(j));
                    build_matched[i] = true;
                    matched = true;
                }
            }
        }
        if !matched && probe_outer {
            bi.push(None);
            pi.push(Some(j));
        }
    }
    if build_outer {
        for (i, m) in build_matched.iter().enumerate() {
            if !m {
                bi.push(Some(i));
                pi.push(None);
            }
        }
    }
    if left_builds {
        (bi, pi)
    } else {
        (pi, bi)
    }
}

/// Sort join: sort index permutations on both keys, linear merge scan.
fn sort_join_indices(
    left: &Table,
    right: &Table,
    cfg: &JoinConfig,
) -> (Vec<Option<usize>>, Vec<Option<usize>>) {
    let lk = left.column(cfg.left_col).as_ref();
    let rk = right.column(cfg.right_col).as_ref();
    let lidx = super::sort::sort_indices(left, cfg.left_col).expect("validated");
    let ridx = super::sort::sort_indices(right, cfg.right_col).expect("validated");

    let left_outer = matches!(cfg.join_type, JoinType::Left | JoinType::FullOuter);
    let right_outer = matches!(cfg.join_type, JoinType::Right | JoinType::FullOuter);

    let mut li: Vec<Option<usize>> = Vec::new();
    let mut ri: Vec<Option<usize>> = Vec::new();
    let (mut i, mut j) = (0usize, 0usize);
    let (nl, nr) = (lidx.len(), ridx.len());

    // Nulls sort first and never match: emit them as outer rows up front.
    while i < nl && !lk.is_valid(lidx[i]) {
        if left_outer {
            li.push(Some(lidx[i]));
            ri.push(None);
        }
        i += 1;
    }
    while j < nr && !rk.is_valid(ridx[j]) {
        if right_outer {
            li.push(None);
            ri.push(Some(ridx[j]));
        }
        j += 1;
    }

    while i < nl && j < nr {
        match cmp_cells_across(lk, lidx[i], rk, ridx[j]) {
            Ordering::Less => {
                if left_outer {
                    li.push(Some(lidx[i]));
                    ri.push(None);
                }
                i += 1;
            }
            Ordering::Greater => {
                if right_outer {
                    li.push(None);
                    ri.push(Some(ridx[j]));
                }
                j += 1;
            }
            Ordering::Equal => {
                // Find the duplicate blocks on both sides, cross product.
                let i_end = {
                    let mut e = i + 1;
                    while e < nl && cmp_cells_across(lk, lidx[e], lk, lidx[i]) == Ordering::Equal {
                        e += 1;
                    }
                    e
                };
                let j_end = {
                    let mut e = j + 1;
                    while e < nr && cmp_cells_across(rk, ridx[e], rk, ridx[j]) == Ordering::Equal {
                        e += 1;
                    }
                    e
                };
                for &il in &lidx[i..i_end] {
                    for &jr in &ridx[j..j_end] {
                        li.push(Some(il));
                        ri.push(Some(jr));
                    }
                }
                i = i_end;
                j = j_end;
            }
        }
    }
    while i < nl {
        if left_outer {
            li.push(Some(lidx[i]));
            ri.push(None);
        }
        i += 1;
    }
    while j < nr {
        if right_outer {
            li.push(None);
            ri.push(Some(ridx[j]));
        }
        j += 1;
    }
    (li, ri)
}

/// Reference nested-loop join (O(n·m)) — the oracle for property tests.
pub fn nested_loop_join(left: &Table, right: &Table, cfg: &JoinConfig) -> Result<Table> {
    let lk = left.column(cfg.left_col).as_ref();
    let rk = right.column(cfg.right_col).as_ref();
    let mut li: Vec<Option<usize>> = Vec::new();
    let mut ri: Vec<Option<usize>> = Vec::new();
    let mut right_matched = vec![false; right.num_rows()];
    for i in 0..left.num_rows() {
        let mut matched = false;
        if lk.is_valid(i) {
            for j in 0..right.num_rows() {
                if rk.is_valid(j) && cmp_cells_across(lk, i, rk, j) == Ordering::Equal {
                    li.push(Some(i));
                    ri.push(Some(j));
                    right_matched[j] = true;
                    matched = true;
                }
            }
        }
        if !matched && matches!(cfg.join_type, JoinType::Left | JoinType::FullOuter) {
            li.push(Some(i));
            ri.push(None);
        }
    }
    if matches!(cfg.join_type, JoinType::Right | JoinType::FullOuter) {
        for (j, m) in right_matched.iter().enumerate() {
            if !m {
                li.push(None);
                ri.push(Some(j));
            }
        }
    }
    materialize(left, right, &li, &ri)
}

/// Schema the join output will have (exposed for planners/builders).
pub fn join_schema(left: &Schema, right: &Schema) -> Schema {
    left.join(right)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::table::Array;
    use std::collections::BTreeMap;

    fn lt() -> Table {
        Table::from_arrays(vec![
            ("k", Array::from_i64(vec![1, 2, 2, 3])),
            ("lv", Array::from_strs(&["a", "b", "c", "d"])),
        ])
        .unwrap()
    }

    fn rt() -> Table {
        Table::from_arrays(vec![
            ("k", Array::from_i64(vec![2, 2, 4])),
            ("rv", Array::from_strs(&["x", "y", "z"])),
        ])
        .unwrap()
    }

    /// Multiset of output rows as sorted strings (order-insensitive cmp).
    fn row_multiset(t: &Table) -> BTreeMap<String, usize> {
        let mut m = BTreeMap::new();
        for r in 0..t.num_rows() {
            let key = (0..t.num_columns())
                .map(|c| crate::table::pretty::cell_to_string(t.column(c), r))
                .collect::<Vec<_>>()
                .join("|");
            *m.entry(key).or_insert(0) += 1;
        }
        m
    }

    fn both(cfg: JoinConfig) -> (Table, Table) {
        let h = join(&lt(), &rt(), &cfg.with_algorithm(JoinAlgorithm::Hash)).unwrap();
        let s = join(&lt(), &rt(), &cfg.with_algorithm(JoinAlgorithm::Sort)).unwrap();
        (h, s)
    }

    #[test]
    fn inner_join_counts() {
        let (h, s) = both(JoinConfig::inner(0, 0));
        // keys 2,2 on left x 2,2 on right = 4 rows
        assert_eq!(h.num_rows(), 4);
        assert_eq!(row_multiset(&h), row_multiset(&s));
        assert_eq!(h.num_columns(), 4);
        assert_eq!(h.schema().field(2).name, "k_r");
    }

    #[test]
    fn left_join_counts() {
        let (h, s) = both(JoinConfig::left(0, 0));
        // 4 matched + keys 1,3 unmatched = 6
        assert_eq!(h.num_rows(), 6);
        assert_eq!(row_multiset(&h), row_multiset(&s));
    }

    #[test]
    fn right_join_counts() {
        let (h, s) = both(JoinConfig::right(0, 0));
        // 4 matched + key 4 unmatched = 5
        assert_eq!(h.num_rows(), 5);
        assert_eq!(row_multiset(&h), row_multiset(&s));
    }

    #[test]
    fn full_outer_counts() {
        let (h, s) = both(JoinConfig::full_outer(0, 0));
        assert_eq!(h.num_rows(), 7);
        assert_eq!(row_multiset(&h), row_multiset(&s));
    }

    #[test]
    fn all_match_nested_loop_oracle() {
        for jt in [JoinType::Inner, JoinType::Left, JoinType::Right, JoinType::FullOuter] {
            for alg in [JoinAlgorithm::Hash, JoinAlgorithm::Sort] {
                let cfg = JoinConfig::new(jt, 0, 0).with_algorithm(alg);
                let got = join(&lt(), &rt(), &cfg).unwrap();
                let want = nested_loop_join(&lt(), &rt(), &cfg).unwrap();
                assert_eq!(
                    row_multiset(&got),
                    row_multiset(&want),
                    "{jt:?}/{alg:?} mismatch"
                );
            }
        }
    }

    #[test]
    fn null_keys_never_match() {
        let l = Table::from_arrays(vec![(
            "k",
            Array::from_i64_opts(vec![None, Some(1)]),
        )])
        .unwrap();
        let r = Table::from_arrays(vec![(
            "k",
            Array::from_i64_opts(vec![None, Some(1)]),
        )])
        .unwrap();
        for alg in [JoinAlgorithm::Hash, JoinAlgorithm::Sort] {
            let inner = join(&l, &r, &JoinConfig::inner(0, 0).with_algorithm(alg)).unwrap();
            assert_eq!(inner.num_rows(), 1, "{alg:?}");
            let full = join(&l, &r, &JoinConfig::full_outer(0, 0).with_algorithm(alg)).unwrap();
            // 1 match + left null row + right null row
            assert_eq!(full.num_rows(), 3, "{alg:?}");
        }
    }

    #[test]
    fn empty_sides() {
        let e = Table::from_arrays(vec![
            ("k", Array::from_i64(vec![])),
            ("lv", Array::from_strs::<&str>(&[])),
        ])
        .unwrap();
        for alg in [JoinAlgorithm::Hash, JoinAlgorithm::Sort] {
            let cfg = JoinConfig::inner(0, 0).with_algorithm(alg);
            assert_eq!(join(&e, &rt(), &cfg).unwrap().num_rows(), 0);
            let cfg = JoinConfig::left(0, 0).with_algorithm(alg);
            assert_eq!(join(&lt(), &e, &cfg).unwrap().num_rows(), 4);
        }
    }

    #[test]
    fn string_keys_join() {
        let l = Table::from_arrays(vec![("k", Array::from_strs(&["a", "b"]))]).unwrap();
        let r = Table::from_arrays(vec![("k", Array::from_strs(&["b", "c"]))]).unwrap();
        for alg in [JoinAlgorithm::Hash, JoinAlgorithm::Sort] {
            let out = join(&l, &r, &JoinConfig::inner(0, 0).with_algorithm(alg)).unwrap();
            assert_eq!(out.num_rows(), 1);
            assert_eq!(out.column(0).as_utf8().unwrap().value(0), "b");
        }
    }

    #[test]
    fn key_type_mismatch_rejected() {
        let l = Table::from_arrays(vec![("k", Array::from_i64(vec![1]))]).unwrap();
        let r = Table::from_arrays(vec![("k", Array::from_f64(vec![1.0]))]).unwrap();
        assert!(join(&l, &r, &JoinConfig::inner(0, 0)).is_err());
    }

    #[test]
    fn join_on_non_first_columns() {
        let l = Table::from_arrays(vec![
            ("x", Array::from_strs(&["p", "q"])),
            ("k", Array::from_i64(vec![7, 8])),
        ])
        .unwrap();
        let r = Table::from_arrays(vec![("k2", Array::from_i64(vec![8, 9]))]).unwrap();
        let out = join(&l, &r, &JoinConfig::inner(1, 0)).unwrap();
        assert_eq!(out.num_rows(), 1);
        assert_eq!(out.column(0).as_utf8().unwrap().value(0), "q");
    }
}
