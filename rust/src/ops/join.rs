//! Join — combine two tables on a key column (§II-B3).
//!
//! Two algorithms, as in the paper:
//!
//! * **Hash join**: build a hash map on the smaller relation's key column,
//!   probe with the larger (the build/probe swap is why Table II's hash
//!   join beats sort join at scale).
//! * **Sort join**: sort both sides on the key (permutation indices only,
//!   morsel-parallel and stable — see [`super::sort`]), then a linear
//!   merge scan with duplicate-block cross products. The scan is
//!   monomorphized over the typed key pair ([`super::sort::KeyCol`]),
//!   so no per-comparison enum dispatch survives in the hot loop.
//!
//! Both produce identical multisets of output rows for all four join
//! semantics (property-tested in `tests/prop_join.rs`).
//!
//! Null semantics: SQL-style — a null key never matches anything (not
//! even another null), but null-keyed rows still appear in outer results.
//!
//! # Morsel-parallel hash join and its canonical output order
//!
//! The hash join is radix-partitioned: both sides' key columns are
//! hashed columnarly ([`super::hash::hash_column`]), rows are split
//! into [`RADIX_PARTITIONS`] partitions by
//! [`super::hash::hash_to_partition`] (equal keys share a hash, so
//! matches never cross partitions), and each partition builds and
//! probes its own chained table — one task per partition on the morsel
//! thread pool. Output order is **canonical and thread-count
//! independent**: matches partition-major, within a partition in
//! ascending probe-row order (build candidates most-recent-first),
//! then unmatched build rows partition-major, ascending. Inputs below
//! [`RADIX_MIN_ROWS`] use a single partition, which reduces exactly to
//! the seed's serial probe order.

use super::hash::{hash_column, radix_ids};
use super::parallel::{map_tasks, parallelism};
use super::partition::partition_indices;
use super::sort::{cmp_cells_across, sort_indices_par, BoolKey, F64Key, I64Key, KeyCol, StrKey};
use crate::error::{Error, Result};
use crate::table::{take::take_table_opt_par, Array, Schema, Table};
use std::cmp::Ordering;
use std::sync::Arc;

/// The four join semantics of Table I.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JoinType {
    Inner,
    Left,
    Right,
    FullOuter,
}

/// Algorithm selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JoinAlgorithm {
    Hash,
    Sort,
}

/// Join configuration: semantics + key columns + algorithm
/// (the `cylon::join::config::JoinConfig` analog).
#[derive(Debug, Clone, Copy)]
pub struct JoinConfig {
    pub join_type: JoinType,
    pub left_col: usize,
    pub right_col: usize,
    pub algorithm: JoinAlgorithm,
}

impl JoinConfig {
    pub fn new(join_type: JoinType, left_col: usize, right_col: usize) -> Self {
        JoinConfig { join_type, left_col, right_col, algorithm: JoinAlgorithm::Hash }
    }

    pub fn inner(l: usize, r: usize) -> Self {
        Self::new(JoinType::Inner, l, r)
    }

    pub fn left(l: usize, r: usize) -> Self {
        Self::new(JoinType::Left, l, r)
    }

    pub fn right(l: usize, r: usize) -> Self {
        Self::new(JoinType::Right, l, r)
    }

    pub fn full_outer(l: usize, r: usize) -> Self {
        Self::new(JoinType::FullOuter, l, r)
    }

    pub fn with_algorithm(mut self, a: JoinAlgorithm) -> Self {
        self.algorithm = a;
        self
    }
}

/// Local join entry point (process-default parallelism).
pub fn join(left: &Table, right: &Table, cfg: &JoinConfig) -> Result<Table> {
    join_par(left, right, cfg, parallelism())
}

/// [`join`] with an explicit thread budget. The output table is bit
/// identical at every `threads` value (see module docs for the
/// canonical order).
pub fn join_par(left: &Table, right: &Table, cfg: &JoinConfig, threads: usize) -> Result<Table> {
    if cfg.left_col >= left.num_columns() || cfg.right_col >= right.num_columns() {
        return Err(Error::invalid("join column out of range"));
    }
    let lk = left.column(cfg.left_col).as_ref();
    let rk = right.column(cfg.right_col).as_ref();
    if lk.data_type() != rk.data_type() {
        return Err(Error::schema(format!(
            "join key types differ: {:?} vs {:?}",
            lk.data_type(),
            rk.data_type()
        )));
    }
    let (li, ri) = match cfg.algorithm {
        JoinAlgorithm::Hash => hash_join_indices(left, right, cfg, threads),
        JoinAlgorithm::Sort => sort_join_indices(left, right, cfg, threads),
    };
    materialize(left, right, &li, &ri, threads)
}

/// [`join_par`] with the hash join's build/probe orientation and radix
/// fan-out pinned by the caller rather than derived from the current
/// input sizes.
///
/// This is the hook behind the planner's predicate pushdown: filtering
/// a join input shrinks it, which could flip which side builds or drop
/// the input under the radix threshold — both change the canonical
/// output *order* (never the multiset). Pinning `build_left` and
/// `partitions` to the decisions the naive plan would have made keeps
/// the pushed-down join's output bit-identical to filtering after the
/// join. Sort joins have no such data-dependent choices and ignore the
/// pins.
pub fn join_par_pinned(
    left: &Table,
    right: &Table,
    cfg: &JoinConfig,
    threads: usize,
    build_left: bool,
    partitions: usize,
) -> Result<Table> {
    if cfg.left_col >= left.num_columns() || cfg.right_col >= right.num_columns() {
        return Err(Error::invalid("join column out of range"));
    }
    if partitions == 0 {
        return Err(Error::invalid("zero radix partitions"));
    }
    let lk = left.column(cfg.left_col).as_ref();
    let rk = right.column(cfg.right_col).as_ref();
    if lk.data_type() != rk.data_type() {
        return Err(Error::schema(format!(
            "join key types differ: {:?} vs {:?}",
            lk.data_type(),
            rk.data_type()
        )));
    }
    let (li, ri) = match cfg.algorithm {
        JoinAlgorithm::Hash => {
            hash_join_indices_with(left, right, cfg, threads, build_left, partitions)
        }
        JoinAlgorithm::Sort => sort_join_indices(left, right, cfg, threads),
    };
    materialize(left, right, &li, &ri, threads)
}

/// Build the output table from matched index pairs (None = outer null);
/// one gather task per output column. `pub(crate)` so the external
/// (spilling) join can assemble per-partition outputs with the exact
/// gather the in-memory join uses.
pub(crate) fn materialize(
    left: &Table,
    right: &Table,
    li: &[Option<usize>],
    ri: &[Option<usize>],
    threads: usize,
) -> Result<Table> {
    debug_assert_eq!(li.len(), ri.len());
    let lt = take_table_opt_par(left, li, threads);
    let rt = take_table_opt_par(right, ri, threads);
    let schema = Arc::new(left.schema().join(right.schema()));
    let mut cols = Vec::with_capacity(lt.num_columns() + rt.num_columns());
    cols.extend(lt.columns().iter().cloned());
    cols.extend(rt.columns().iter().cloned());
    Table::try_new(schema, cols)
}

const CHAIN_END: u32 = u32::MAX;

/// Rows (build + probe) below which the hash join stays
/// single-partition — the radix split only pays off once per-partition
/// tables stop fitting in cache / there is enough work per thread.
pub const RADIX_MIN_ROWS: usize = 1 << 14;

/// Fixed radix fan-out for large hash joins. Deliberately **not**
/// derived from the thread count, so the canonical output order is the
/// same at every `parallelism`.
pub const RADIX_PARTITIONS: usize = 64;

/// One radix partition's matched pairs + unmatched build rows.
struct PartJoin {
    bi: Vec<Option<usize>>,
    pi: Vec<Option<usize>>,
    unmatched_build: Vec<usize>,
}

/// Build a chained hash table over this partition's build rows and
/// probe it with the partition's probe rows, in ascending row order.
/// `bh`/`ph` are the full-column hashes indexed by global row id.
/// Generic over the typed key pair ([`KeyCol`]) so the probe's
/// candidate-equality check is a primitive compare, not enum dispatch.
fn join_partition<K: KeyCol>(
    bk: K,
    pk: K,
    bh: &[u32],
    ph: &[u32],
    build_rows: &[usize],
    probe_rows: &[usize],
    probe_outer: bool,
) -> PartJoin {
    // Flat chained-index table: `first[bucket]` heads a list threaded
    // through `next[slot]`. One allocation each, no per-bucket Vecs —
    // ~2–3× faster to build than `HashMap<u32, Vec>` and the probe
    // walk is cache-linear in `next`. Null build keys are never
    // inserted (SQL: null matches nothing) but stay tracked for outer
    // emission.
    let n = build_rows.len();
    let buckets = (n.max(1) * 2).next_power_of_two();
    let mask = (buckets - 1) as u32;
    let mut first = vec![CHAIN_END; buckets];
    let mut next = vec![CHAIN_END; n];
    for (slot, &row) in build_rows.iter().enumerate() {
        if bk.valid(row) {
            let b = (bh[row] & mask) as usize;
            next[slot] = first[b];
            first[b] = slot as u32;
        }
    }
    let mut matched = vec![false; n];
    let mut bi: Vec<Option<usize>> = Vec::new();
    let mut pi: Vec<Option<usize>> = Vec::new();
    for &j in probe_rows {
        let mut any = false;
        if pk.valid(j) {
            let h = ph[j];
            let mut cur = first[(h & mask) as usize];
            while cur != CHAIN_END {
                let slot = cur as usize;
                cur = next[slot];
                let i = build_rows[slot];
                // Both rows are valid here (null build keys were never
                // inserted), so the typed value compare suffices.
                if bh[i] == h && bk.cmp_values(i, &pk, j) == Ordering::Equal {
                    bi.push(Some(i));
                    pi.push(Some(j));
                    matched[slot] = true;
                    any = true;
                }
            }
        }
        if !any && probe_outer {
            bi.push(None);
            pi.push(Some(j));
        }
    }
    let unmatched_build = build_rows
        .iter()
        .enumerate()
        .filter(|(slot, _)| !matched[*slot])
        .map(|(_, &row)| row)
        .collect();
    PartJoin { bi, pi, unmatched_build }
}

/// Which sides emit outer rows, given the join semantics and which
/// side builds: `(probe_outer, build_outer)`. Factored out so the
/// external (spilling) join replays the in-memory decision exactly.
pub(crate) fn outer_flags(join_type: JoinType, left_builds: bool) -> (bool, bool) {
    let probe_outer = match (join_type, left_builds) {
        (JoinType::Inner, _) => false,
        (JoinType::FullOuter, _) => true,
        (JoinType::Left, true) => false,  // left is build side
        (JoinType::Left, false) => true,  // left is probe side
        (JoinType::Right, true) => true,  // right is probe side
        (JoinType::Right, false) => false,
    };
    let build_outer = match (join_type, left_builds) {
        (JoinType::Inner, _) => false,
        (JoinType::FullOuter, _) => true,
        (JoinType::Left, true) => true,
        (JoinType::Left, false) => false,
        (JoinType::Right, true) => false,
        (JoinType::Right, false) => true,
    };
    (probe_outer, build_outer)
}

/// Join one radix partition whose sides are already isolated as whole
/// tables (local row ids `0..n`). Runs the exact per-partition kernel
/// of the in-memory hash join — same bucket count, same ascending
/// insertion order, same most-recent-first probe walk — over hashes
/// recomputed columnarly on the chunk (hashes are cell-wise, so chunk
/// hashes equal the full-column hashes of the same rows). Returns
/// `(build_idx, probe_idx, unmatched_build_local_rows)`; used by
/// `external::join` to process one spilled partition pair at a time
/// while staying bit-identical to the in-memory join.
pub(crate) fn join_partition_tables(
    build: &Table,
    build_col: usize,
    probe: &Table,
    probe_col: usize,
    threads: usize,
    probe_outer: bool,
) -> Result<(Vec<Option<usize>>, Vec<Option<usize>>, Vec<usize>)> {
    let bk = build.column(build_col).as_ref();
    let pk = probe.column(probe_col).as_ref();
    let bh = hash_column(bk, threads);
    let ph = hash_column(pk, threads);
    let build_rows: Vec<usize> = (0..build.num_rows()).collect();
    let probe_rows: Vec<usize> = (0..probe.num_rows()).collect();
    let part = match (bk, pk) {
        (Array::Int64(x), Array::Int64(y)) => {
            join_partition(I64Key(x), I64Key(y), &bh, &ph, &build_rows, &probe_rows, probe_outer)
        }
        (Array::Float64(x), Array::Float64(y)) => {
            join_partition(F64Key(x), F64Key(y), &bh, &ph, &build_rows, &probe_rows, probe_outer)
        }
        (Array::Utf8(x), Array::Utf8(y)) => {
            join_partition(StrKey(x), StrKey(y), &bh, &ph, &build_rows, &probe_rows, probe_outer)
        }
        (Array::Bool(x), Array::Bool(y)) => {
            join_partition(BoolKey(x), BoolKey(y), &bh, &ph, &build_rows, &probe_rows, probe_outer)
        }
        _ => {
            return Err(Error::schema(format!(
                "join key types differ: {:?} vs {:?}",
                bk.data_type(),
                pk.data_type()
            )))
        }
    };
    Ok((part.bi, part.pi, part.unmatched_build))
}

/// The radix fan-out the hash join (and the radix set operators) use
/// for `rows` total input rows: single-partition below
/// [`RADIX_MIN_ROWS`], [`RADIX_PARTITIONS`] above. Pure function of the
/// row count — the planner pins it when predicate pushdown changes an
/// operator's input cardinality, so the optimized operator replays the
/// naive plan's partition regime bit-for-bit.
pub fn radix_fanout(rows: usize) -> usize {
    if rows < RADIX_MIN_ROWS {
        1
    } else {
        RADIX_PARTITIONS
    }
}

/// Hash join: build on the smaller side, probe with the larger,
/// radix-partitioned across the morsel thread pool.
fn hash_join_indices(
    left: &Table,
    right: &Table,
    cfg: &JoinConfig,
    threads: usize,
) -> (Vec<Option<usize>>, Vec<Option<usize>>) {
    // Swap so `build` is the smaller relation; partition count is a
    // pure function of the input size (never of `threads`), so the
    // partition-major output order is canonical.
    hash_join_indices_with(
        left,
        right,
        cfg,
        threads,
        left.num_rows() <= right.num_rows(),
        radix_fanout(left.num_rows() + right.num_rows()),
    )
}

/// [`hash_join_indices`] with the orientation (which side builds) and
/// radix fan-out chosen by the caller instead of derived from the
/// current input sizes. The output order is canonical *given* those
/// two choices; [`join_par_pinned`] exposes this so the query planner
/// can replay the pre-pushdown decisions.
fn hash_join_indices_with(
    left: &Table,
    right: &Table,
    cfg: &JoinConfig,
    threads: usize,
    left_builds: bool,
    p: usize,
) -> (Vec<Option<usize>>, Vec<Option<usize>>) {
    let (build_t, build_col, probe_t, probe_col) = if left_builds {
        (left, cfg.left_col, right, cfg.right_col)
    } else {
        (right, cfg.right_col, left, cfg.left_col)
    };
    let bk = build_t.column(build_col).as_ref();
    let pk = probe_t.column(probe_col).as_ref();
    let (nb, np) = (build_t.num_rows(), probe_t.num_rows());

    // Columnar key hashes, one pass per side (shared by radix split,
    // chain build, and probe).
    let bh = hash_column(bk, threads);
    let ph = hash_column(pk, threads);

    let (probe_outer, build_outer) = outer_flags(cfg.join_type, left_builds);

    let (build_parts, probe_parts) = if p == 1 {
        (vec![(0..nb).collect::<Vec<usize>>()], vec![(0..np).collect::<Vec<usize>>()])
    } else {
        // Equal keys have equal hashes, so matches never cross
        // partitions; null rows ride along on the null-sentinel hash.
        (
            partition_indices(&radix_ids(&bh, p, threads), p),
            partition_indices(&radix_ids(&ph, p, threads), p),
        )
    };

    // Resolve the key pair to typed columns once; every partition task
    // then probes with monomorphized primitive compares. The shared
    // arguments travel as one tuple so each match arm stays short.
    type PartArgs<'x> =
        (&'x [u32], &'x [u32], &'x [Vec<usize>], &'x [Vec<usize>], bool, usize, usize);
    fn run_partitions<K: KeyCol>(bk: K, pk: K, args: PartArgs<'_>) -> Vec<PartJoin> {
        let (bh, ph, build_parts, probe_parts, probe_outer, p, threads) = args;
        map_tasks(p, threads, |pid| {
            join_partition(bk, pk, bh, ph, &build_parts[pid], &probe_parts[pid], probe_outer)
        })
    }
    let args = (&bh[..], &ph[..], &build_parts[..], &probe_parts[..], probe_outer, p, threads);
    let parts = match (bk, pk) {
        (Array::Int64(x), Array::Int64(y)) => run_partitions(I64Key(x), I64Key(y), args),
        (Array::Float64(x), Array::Float64(y)) => run_partitions(F64Key(x), F64Key(y), args),
        (Array::Utf8(x), Array::Utf8(y)) => run_partitions(StrKey(x), StrKey(y), args),
        (Array::Bool(x), Array::Bool(y)) => run_partitions(BoolKey(x), BoolKey(y), args),
        _ => unreachable!("join key types validated by join_par"),
    };

    // Canonical assembly: matches partition-major, then (if outer)
    // unmatched build rows partition-major.
    let mut total: usize = parts.iter().map(|x| x.bi.len()).sum();
    if build_outer {
        total += parts.iter().map(|x| x.unmatched_build.len()).sum::<usize>();
    }
    let mut bi: Vec<Option<usize>> = Vec::with_capacity(total);
    let mut pi: Vec<Option<usize>> = Vec::with_capacity(total);
    for part in &parts {
        bi.extend_from_slice(&part.bi);
        pi.extend_from_slice(&part.pi);
    }
    if build_outer {
        for part in &parts {
            for &i in &part.unmatched_build {
                bi.push(Some(i));
                pi.push(None);
            }
        }
    }
    if left_builds {
        (bi, pi)
    } else {
        (pi, bi)
    }
}

/// Sort join: sort index permutations on both keys (morsel-parallel,
/// stable), then a linear merge scan with duplicate-block cross
/// products. The scan is monomorphized over the typed key pair
/// ([`KeyCol`]) — one enum resolution, primitive compares throughout.
fn sort_join_indices(
    left: &Table,
    right: &Table,
    cfg: &JoinConfig,
    threads: usize,
) -> (Vec<Option<usize>>, Vec<Option<usize>>) {
    let lk = left.column(cfg.left_col).as_ref();
    let rk = right.column(cfg.right_col).as_ref();
    let lidx = sort_indices_par(left, cfg.left_col, threads).expect("validated");
    let ridx = sort_indices_par(right, cfg.right_col, threads).expect("validated");

    let left_outer = matches!(cfg.join_type, JoinType::Left | JoinType::FullOuter);
    let right_outer = matches!(cfg.join_type, JoinType::Right | JoinType::FullOuter);

    match (lk, rk) {
        (Array::Int64(x), Array::Int64(y)) => {
            sort_join_scan(I64Key(x), I64Key(y), &lidx, &ridx, left_outer, right_outer)
        }
        (Array::Float64(x), Array::Float64(y)) => {
            sort_join_scan(F64Key(x), F64Key(y), &lidx, &ridx, left_outer, right_outer)
        }
        (Array::Utf8(x), Array::Utf8(y)) => {
            sort_join_scan(StrKey(x), StrKey(y), &lidx, &ridx, left_outer, right_outer)
        }
        (Array::Bool(x), Array::Bool(y)) => {
            sort_join_scan(BoolKey(x), BoolKey(y), &lidx, &ridx, left_outer, right_outer)
        }
        _ => unreachable!("join key types validated by join_par"),
    }
}

/// The sort-join merge scan over pre-sorted permutations.
fn sort_join_scan<K: KeyCol>(
    lk: K,
    rk: K,
    lidx: &[usize],
    ridx: &[usize],
    left_outer: bool,
    right_outer: bool,
) -> (Vec<Option<usize>>, Vec<Option<usize>>) {
    let mut li: Vec<Option<usize>> = Vec::new();
    let mut ri: Vec<Option<usize>> = Vec::new();
    let (mut i, mut j) = (0usize, 0usize);
    let (nl, nr) = (lidx.len(), ridx.len());

    // Nulls sort first and never match: emit them as outer rows up front.
    while i < nl && !lk.valid(lidx[i]) {
        if left_outer {
            li.push(Some(lidx[i]));
            ri.push(None);
        }
        i += 1;
    }
    while j < nr && !rk.valid(ridx[j]) {
        if right_outer {
            li.push(None);
            ri.push(Some(ridx[j]));
        }
        j += 1;
    }

    while i < nl && j < nr {
        // Both heads are valid (the null prefixes are consumed above
        // and blocks below only advance past valid rows).
        match lk.cmp_values(lidx[i], &rk, ridx[j]) {
            Ordering::Less => {
                if left_outer {
                    li.push(Some(lidx[i]));
                    ri.push(None);
                }
                i += 1;
            }
            Ordering::Greater => {
                if right_outer {
                    li.push(None);
                    ri.push(Some(ridx[j]));
                }
                j += 1;
            }
            Ordering::Equal => {
                // Find the duplicate blocks on both sides, cross product.
                let i_end = {
                    let mut e = i + 1;
                    while e < nl && lk.cmp_values(lidx[e], &lk, lidx[i]) == Ordering::Equal {
                        e += 1;
                    }
                    e
                };
                let j_end = {
                    let mut e = j + 1;
                    while e < nr && rk.cmp_values(ridx[e], &rk, ridx[j]) == Ordering::Equal {
                        e += 1;
                    }
                    e
                };
                for &il in &lidx[i..i_end] {
                    for &jr in &ridx[j..j_end] {
                        li.push(Some(il));
                        ri.push(Some(jr));
                    }
                }
                i = i_end;
                j = j_end;
            }
        }
    }
    while i < nl {
        if left_outer {
            li.push(Some(lidx[i]));
            ri.push(None);
        }
        i += 1;
    }
    while j < nr {
        if right_outer {
            li.push(None);
            ri.push(Some(ridx[j]));
        }
        j += 1;
    }
    (li, ri)
}

/// Reference nested-loop join (O(n·m)) — the oracle for property tests.
pub fn nested_loop_join(left: &Table, right: &Table, cfg: &JoinConfig) -> Result<Table> {
    let lk = left.column(cfg.left_col).as_ref();
    let rk = right.column(cfg.right_col).as_ref();
    let mut li: Vec<Option<usize>> = Vec::new();
    let mut ri: Vec<Option<usize>> = Vec::new();
    let mut right_matched = vec![false; right.num_rows()];
    for i in 0..left.num_rows() {
        let mut matched = false;
        if lk.is_valid(i) {
            for j in 0..right.num_rows() {
                if rk.is_valid(j) && cmp_cells_across(lk, i, rk, j) == Ordering::Equal {
                    li.push(Some(i));
                    ri.push(Some(j));
                    right_matched[j] = true;
                    matched = true;
                }
            }
        }
        if !matched && matches!(cfg.join_type, JoinType::Left | JoinType::FullOuter) {
            li.push(Some(i));
            ri.push(None);
        }
    }
    if matches!(cfg.join_type, JoinType::Right | JoinType::FullOuter) {
        for (j, m) in right_matched.iter().enumerate() {
            if !m {
                li.push(None);
                ri.push(Some(j));
            }
        }
    }
    materialize(left, right, &li, &ri, 1)
}

/// Schema the join output will have (exposed for planners/builders).
pub fn join_schema(left: &Schema, right: &Schema) -> Schema {
    left.join(right)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::table::Array;
    use std::collections::BTreeMap;

    fn lt() -> Table {
        Table::from_arrays(vec![
            ("k", Array::from_i64(vec![1, 2, 2, 3])),
            ("lv", Array::from_strs(&["a", "b", "c", "d"])),
        ])
        .unwrap()
    }

    fn rt() -> Table {
        Table::from_arrays(vec![
            ("k", Array::from_i64(vec![2, 2, 4])),
            ("rv", Array::from_strs(&["x", "y", "z"])),
        ])
        .unwrap()
    }

    /// Multiset of output rows as sorted strings (order-insensitive cmp).
    fn row_multiset(t: &Table) -> BTreeMap<String, usize> {
        let mut m = BTreeMap::new();
        for r in 0..t.num_rows() {
            let key = (0..t.num_columns())
                .map(|c| crate::table::pretty::cell_to_string(t.column(c), r))
                .collect::<Vec<_>>()
                .join("|");
            *m.entry(key).or_insert(0) += 1;
        }
        m
    }

    fn both(cfg: JoinConfig) -> (Table, Table) {
        let h = join(&lt(), &rt(), &cfg.with_algorithm(JoinAlgorithm::Hash)).unwrap();
        let s = join(&lt(), &rt(), &cfg.with_algorithm(JoinAlgorithm::Sort)).unwrap();
        (h, s)
    }

    #[test]
    fn inner_join_counts() {
        let (h, s) = both(JoinConfig::inner(0, 0));
        // keys 2,2 on left x 2,2 on right = 4 rows
        assert_eq!(h.num_rows(), 4);
        assert_eq!(row_multiset(&h), row_multiset(&s));
        assert_eq!(h.num_columns(), 4);
        assert_eq!(h.schema().field(2).name, "k_r");
    }

    #[test]
    fn left_join_counts() {
        let (h, s) = both(JoinConfig::left(0, 0));
        // 4 matched + keys 1,3 unmatched = 6
        assert_eq!(h.num_rows(), 6);
        assert_eq!(row_multiset(&h), row_multiset(&s));
    }

    #[test]
    fn right_join_counts() {
        let (h, s) = both(JoinConfig::right(0, 0));
        // 4 matched + key 4 unmatched = 5
        assert_eq!(h.num_rows(), 5);
        assert_eq!(row_multiset(&h), row_multiset(&s));
    }

    #[test]
    fn full_outer_counts() {
        let (h, s) = both(JoinConfig::full_outer(0, 0));
        assert_eq!(h.num_rows(), 7);
        assert_eq!(row_multiset(&h), row_multiset(&s));
    }

    #[test]
    fn all_match_nested_loop_oracle() {
        for jt in [JoinType::Inner, JoinType::Left, JoinType::Right, JoinType::FullOuter] {
            for alg in [JoinAlgorithm::Hash, JoinAlgorithm::Sort] {
                let cfg = JoinConfig::new(jt, 0, 0).with_algorithm(alg);
                let got = join(&lt(), &rt(), &cfg).unwrap();
                let want = nested_loop_join(&lt(), &rt(), &cfg).unwrap();
                assert_eq!(
                    row_multiset(&got),
                    row_multiset(&want),
                    "{jt:?}/{alg:?} mismatch"
                );
            }
        }
    }

    #[test]
    fn null_keys_never_match() {
        let l = Table::from_arrays(vec![(
            "k",
            Array::from_i64_opts(vec![None, Some(1)]),
        )])
        .unwrap();
        let r = Table::from_arrays(vec![(
            "k",
            Array::from_i64_opts(vec![None, Some(1)]),
        )])
        .unwrap();
        for alg in [JoinAlgorithm::Hash, JoinAlgorithm::Sort] {
            let inner = join(&l, &r, &JoinConfig::inner(0, 0).with_algorithm(alg)).unwrap();
            assert_eq!(inner.num_rows(), 1, "{alg:?}");
            let full = join(&l, &r, &JoinConfig::full_outer(0, 0).with_algorithm(alg)).unwrap();
            // 1 match + left null row + right null row
            assert_eq!(full.num_rows(), 3, "{alg:?}");
        }
    }

    #[test]
    fn empty_sides() {
        let e = Table::from_arrays(vec![
            ("k", Array::from_i64(vec![])),
            ("lv", Array::from_strs::<&str>(&[])),
        ])
        .unwrap();
        for alg in [JoinAlgorithm::Hash, JoinAlgorithm::Sort] {
            let cfg = JoinConfig::inner(0, 0).with_algorithm(alg);
            assert_eq!(join(&e, &rt(), &cfg).unwrap().num_rows(), 0);
            let cfg = JoinConfig::left(0, 0).with_algorithm(alg);
            assert_eq!(join(&lt(), &e, &cfg).unwrap().num_rows(), 4);
        }
    }

    #[test]
    fn string_keys_join() {
        let l = Table::from_arrays(vec![("k", Array::from_strs(&["a", "b"]))]).unwrap();
        let r = Table::from_arrays(vec![("k", Array::from_strs(&["b", "c"]))]).unwrap();
        for alg in [JoinAlgorithm::Hash, JoinAlgorithm::Sort] {
            let out = join(&l, &r, &JoinConfig::inner(0, 0).with_algorithm(alg)).unwrap();
            assert_eq!(out.num_rows(), 1);
            assert_eq!(out.column(0).as_utf8().unwrap().value(0), "b");
        }
    }

    #[test]
    fn key_type_mismatch_rejected() {
        let l = Table::from_arrays(vec![("k", Array::from_i64(vec![1]))]).unwrap();
        let r = Table::from_arrays(vec![("k", Array::from_f64(vec![1.0]))]).unwrap();
        assert!(join(&l, &r, &JoinConfig::inner(0, 0)).is_err());
    }

    #[test]
    fn join_par_bit_identical_across_thread_counts() {
        for jt in [JoinType::Inner, JoinType::Left, JoinType::Right, JoinType::FullOuter] {
            let cfg = JoinConfig::new(jt, 0, 0);
            let serial = join_par(&lt(), &rt(), &cfg, 1).unwrap();
            for threads in [2usize, 7] {
                let par = join_par(&lt(), &rt(), &cfg, threads).unwrap();
                assert!(par.data_equals(&serial), "{jt:?} threads={threads}");
            }
        }
    }

    #[test]
    fn radix_path_matches_single_partition_multiset() {
        // Big enough to cross RADIX_MIN_ROWS so the radix path runs;
        // verify against the sort join (same multiset, different order).
        let n = (RADIX_MIN_ROWS / 2 + 100) as i64;
        let l = Table::from_arrays(vec![("k", Array::from_i64((0..n).map(|i| i % 97).collect()))])
            .unwrap();
        let r = Table::from_arrays(vec![("k", Array::from_i64((0..n).map(|i| i * 2).collect()))])
            .unwrap();
        let cfg = JoinConfig::inner(0, 0);
        let hash = join_par(&l, &r, &cfg, 4).unwrap();
        let sort = join(&l, &r, &cfg.with_algorithm(JoinAlgorithm::Sort)).unwrap();
        assert_eq!(row_multiset(&hash), row_multiset(&sort));
        // And the radix order itself is thread-count independent.
        let serial = join_par(&l, &r, &cfg, 1).unwrap();
        assert!(hash.data_equals(&serial));
    }

    #[test]
    fn join_on_non_first_columns() {
        let l = Table::from_arrays(vec![
            ("x", Array::from_strs(&["p", "q"])),
            ("k", Array::from_i64(vec![7, 8])),
        ])
        .unwrap();
        let r = Table::from_arrays(vec![("k2", Array::from_i64(vec![8, 9]))]).unwrap();
        let out = join(&l, &r, &JoinConfig::inner(1, 0)).unwrap();
        assert_eq!(out.num_rows(), 1);
        assert_eq!(out.column(0).as_utf8().unwrap().value(0), "q");
    }

    #[test]
    fn pinned_join_with_default_pins_equals_join_par() {
        let l = crate::io::generator::paper_table(500, 0.8, 0x71A);
        let r = crate::io::generator::paper_table(700, 0.8, 0x71B);
        let cfg = JoinConfig::inner(0, 0);
        let want = join_par(&l, &r, &cfg, 2).unwrap();
        let got = join_par_pinned(
            &l,
            &r,
            &cfg,
            2,
            l.num_rows() <= r.num_rows(),
            radix_fanout(l.num_rows() + r.num_rows()),
        )
        .unwrap();
        assert!(got.data_equals(&want));
    }

    #[test]
    fn pinned_join_replays_prefilter_decisions_bit_identically() {
        // The planner's pushdown contract: join-then-filter equals
        // filter-then-pinned-join *including row order*, even when the
        // filter shrinks a side enough to flip the default build side.
        let l = crate::io::generator::paper_table(900, 0.8, 0xF1A);
        let r = crate::io::generator::paper_table(400, 0.8, 0xF1B);
        for jt in [JoinType::Inner, JoinType::Left] {
            let cfg = JoinConfig::new(jt, 0, 0);
            let joined = join_par(&l, &r, &cfg, 3).unwrap();
            // pred on a left column: keep c1 < 0.25 (kills ~3/4 of l,
            // so |l'| < |r| while |l| > |r|).
            let pred = crate::ops::expr::Expr::col(1)
                .lt(crate::ops::expr::Expr::lit_f64(0.25));
            let naive = crate::ops::expr::filter(&joined, &pred).unwrap();
            let lf = crate::ops::expr::filter(&l, &pred).unwrap();
            assert!(lf.num_rows() < r.num_rows() && l.num_rows() > r.num_rows());
            let pushed = join_par_pinned(
                &lf,
                &r,
                &cfg,
                3,
                l.num_rows() <= r.num_rows(),
                radix_fanout(l.num_rows() + r.num_rows()),
            )
            .unwrap();
            assert!(pushed.data_equals(&naive), "join_type {jt:?}");
        }
    }
}
