//! Morsel-driven parallel execution for the local operators.
//!
//! The paper's performance claim (§IV) rests on local operators that
//! saturate the cores of each node. This module is the (stdlib-only)
//! engine behind that: inputs are split into fixed-size **morsels**
//! (chunks of [`MORSEL_ROWS`] rows) and a small pool of scoped threads
//! pulls morsels off a shared atomic counter.
//!
//! # Determinism contract
//!
//! Every parallel operator built on these helpers produces **bit
//! identical output at any thread count**, because nothing observable
//! depends on scheduling:
//!
//! * morsel boundaries are a fixed function of the input length
//!   ([`MORSEL_ROWS`]), *never* of the thread count;
//! * [`map_morsels`] / [`map_tasks`] return results in task order, no
//!   matter which thread computed them;
//! * threads share no mutable state beyond the task counter.
//!
//! Callers therefore only choose *how fast* an operator runs, never
//! *what* it returns — the serial/parallel equivalence property tests
//! in `tests/prop_parallel.rs` pin this at `parallelism ∈ {1, 2, 7}`.
//!
//! # Lifecycle: panic isolation and cancellation
//!
//! Task bodies run under `catch_unwind`: a panicking task never
//! unwinds through a worker thread. The first payload (in task order)
//! is captured, siblings stop claiming tasks, and the caller sees one
//! clean re-panic on the infallible paths ([`map_tasks`],
//! [`map_morsels`], [`for_each_slice_mut`]) or a structured
//! `Error::Internal` on the fallible one ([`try_map_morsels`]). Joins
//! never `expect` a worker result, so a panicked worker can never
//! trigger a second panic while the first is unwinding.
//!
//! [`try_map_morsels`] additionally honors the ambient
//! [`crate::lifecycle::QueryControl`] (installed per query by the
//! worker harness): cancellation or deadline expiry stops the grid at
//! the next morsel boundary with the structured lifecycle error. The
//! polls are pure atomic reads and a query that is *not* cancelled
//! runs the identical morsel schedule, preserving the determinism
//! contract above.
//!
//! # The parallelism knob
//!
//! [`parallelism`] resolves the process-wide default thread budget:
//! an explicit [`set_parallelism`] wins, then the `RYLON_PARALLELISM`
//! environment variable, then the machine's available parallelism.
//! [`crate::ctx::CylonContext`] carries a per-worker knob derived from
//! it (divided by the in-process world size) so co-located workers
//! share the machine instead of oversubscribing it.

use crate::error::{Error, Result};
use crate::lifecycle::{current_control, QueryControl};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Rows per morsel. Fixed (not derived from the thread count) so that
/// chunk boundaries — and thus any per-chunk floating-point reduction
/// order — are a pure function of the input.
pub const MORSEL_ROWS: usize = 1 << 16;

/// Row count below which task-per-column / task-per-partition fan-out
/// is not worth a thread spawn; callers drop to 1 thread under it.
/// (Purely a speed heuristic — results are identical either way.)
pub const PAR_MIN_ROWS: usize = 1 << 12;

/// Process-wide override; 0 = unset (fall back to env / hardware).
static PARALLELISM: AtomicUsize = AtomicUsize::new(0);

fn default_parallelism() -> usize {
    static DEFAULT: OnceLock<usize> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        std::env::var("RYLON_PARALLELISM")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

/// Set the process-wide parallelism knob (0 restores the default:
/// `RYLON_PARALLELISM` env var, else hardware parallelism).
pub fn set_parallelism(n: usize) {
    PARALLELISM.store(n, Ordering::Relaxed);
}

/// The process-wide thread budget local operators use when no explicit
/// per-call parallelism is given.
pub fn parallelism() -> usize {
    match PARALLELISM.load(Ordering::Relaxed) {
        0 => default_parallelism(),
        n => n,
    }
}

/// How a task (or its worker) failed inside the grid.
enum TaskFailure {
    /// The task body returned an error (fallible grids only).
    Err(Error),
    /// The task body panicked; the payload message was captured.
    Panicked(String),
}

/// What a whole grid run produced.
enum GridOutcome<T> {
    /// Every task completed; results in task order.
    Done(Vec<T>),
    /// The first failure **in task order** (deterministic: tasks are
    /// claimed as a monotone prefix, so the minimal failing index is
    /// always claimed and run before any later task).
    Failed(usize, TaskFailure),
    /// The attached [`QueryControl`] stopped the grid early; carries
    /// the structured lifecycle error.
    Stopped(Error),
}

/// Render a captured panic payload (the `&str` / `String` payloads
/// `panic!` produces; anything else gets a placeholder).
fn panic_msg(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// The shared engine behind every fan-out in this module: run `n`
/// tasks on up to `threads` scoped threads, pulling task indices off
/// one atomic counter, with task bodies isolated under
/// `catch_unwind`. Workers therefore never unwind; joins are plain
/// and can never double-panic. When `ctl` is given, workers stop
/// claiming tasks once it requests a stop (pure atomic polls — the
/// claim schedule of an uncancelled run is untouched).
fn run_grid<T, F>(
    n: usize,
    threads: usize,
    ctl: Option<&QueryControl>,
    f: F,
) -> GridOutcome<T>
where
    T: Send,
    F: Fn(usize) -> Result<T> + Sync,
{
    if let Some(c) = ctl {
        if let Err(e) = c.check() {
            return GridOutcome::Stopped(e);
        }
    }
    // One span per grid — NOT per morsel (a grid can run thousands of
    // tasks; per-task spans would blow the span cap and the timing
    // overhead would no longer be "one branch per site"). Per-worker
    // busy time rides along as `w<i>_busy_ns` counters, which the
    // Chrome exporter expands into per-worker timeline lanes. All
    // measurement is gated on `traced`, so a disabled sink costs the
    // TLS check in `span()` and nothing per task.
    let mut span = crate::trace::span(crate::trace::SpanKind::Grid, "grid");
    let traced = span.active();
    span.add("tasks", n as u64);
    let threads = threads.max(1).min(n);
    span.add("threads", threads.max(1) as u64);
    if threads <= 1 {
        let grid_t0 = std::time::Instant::now();
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            if let Some(c) = ctl {
                if i > 0 {
                    if let Err(e) = c.check() {
                        return GridOutcome::Stopped(e);
                    }
                }
            }
            match catch_unwind(AssertUnwindSafe(|| f(i))) {
                Ok(Ok(v)) => out.push(v),
                Ok(Err(e)) => return GridOutcome::Failed(i, TaskFailure::Err(e)),
                Err(p) => {
                    if let Some(c) = ctl {
                        c.note_panic();
                    }
                    return GridOutcome::Failed(i, TaskFailure::Panicked(panic_msg(p)));
                }
            }
        }
        if traced {
            span.add("w0_busy_ns", grid_t0.elapsed().as_nanos() as u64);
        }
        return GridOutcome::Done(out);
    }
    let next = AtomicUsize::new(0);
    let stop = AtomicBool::new(false);
    let mut joined_failure: Option<TaskFailure> = None;
    let collected = std::thread::scope(|s| {
        let (next, stop, f) = (&next, &stop, &f);
        let mut handles = Vec::with_capacity(threads);
        for _ in 0..threads {
            handles.push(s.spawn(move || {
                let mut local: Vec<(usize, std::result::Result<T, TaskFailure>)> =
                    Vec::new();
                let mut busy_ns = 0u64;
                loop {
                    if stop.load(Ordering::Relaxed)
                        || ctl.is_some_and(|c| c.stop_requested())
                    {
                        break;
                    }
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let task_t0 = traced.then(std::time::Instant::now);
                    match catch_unwind(AssertUnwindSafe(|| f(i))) {
                        Ok(Ok(v)) => local.push((i, Ok(v))),
                        Ok(Err(e)) => {
                            stop.store(true, Ordering::Relaxed);
                            local.push((i, Err(TaskFailure::Err(e))));
                        }
                        Err(p) => {
                            if let Some(c) = ctl {
                                c.note_panic();
                            }
                            stop.store(true, Ordering::Relaxed);
                            local.push((i, Err(TaskFailure::Panicked(panic_msg(p)))));
                        }
                    }
                    if let Some(t0) = task_t0 {
                        busy_ns += t0.elapsed().as_nanos() as u64;
                    }
                }
                (local, busy_ns)
            }));
        }
        let mut parts = Vec::with_capacity(threads);
        for (w, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok((part, busy_ns)) => {
                    if traced {
                        span.add(&format!("w{w}_busy_ns"), busy_ns);
                    }
                    parts.push(part);
                }
                // Worker bodies catch every unwind, so this arm is
                // close to unreachable — but if a worker still died,
                // record it instead of re-panicking (a panic here
                // while another panic unwinds would abort the
                // process).
                Err(p) => joined_failure = Some(TaskFailure::Panicked(panic_msg(p))),
            }
        }
        parts
    });
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let mut first: Option<(usize, TaskFailure)> = None;
    for part in collected {
        for (i, r) in part {
            match r {
                Ok(v) => out[i] = Some(v),
                Err(fail) => {
                    if first.as_ref().map_or(true, |(j, _)| i < *j) {
                        first = Some((i, fail));
                    }
                }
            }
        }
    }
    if let Some((i, fail)) = first {
        return GridOutcome::Failed(i, fail);
    }
    if let Some(fail) = joined_failure {
        return GridOutcome::Failed(n, fail);
    }
    if out.iter().any(|v| v.is_none()) {
        // Only a control stop leaves gaps: failures are recorded and
        // handled above, and an uncancelled grid claims every task.
        let e = ctl
            .and_then(|c| c.check().err())
            .unwrap_or_else(|| Error::cancelled("query cancelled mid-grid"));
        return GridOutcome::Stopped(e);
    }
    GridOutcome::Done(out.into_iter().map(|v| v.expect("checked above")).collect())
}

/// Run `n` independent tasks on up to `threads` scoped threads and
/// return their results **in task order**. Tasks are pulled from a
/// shared atomic counter (morsel-driven work stealing), so skew in
/// per-task cost balances out. `threads <= 1` (or `n <= 1`) runs
/// inline with zero thread spawns.
///
/// A panicking task is contained in its worker and re-raised **once**
/// on the calling thread with the captured payload message — the
/// process never aborts from a worker unwind.
pub fn map_tasks<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    match run_grid(n, threads, None, |i| Ok(f(i))) {
        GridOutcome::Done(v) => v,
        GridOutcome::Failed(i, TaskFailure::Panicked(msg)) => {
            panic!("morsel worker panicked (task {i}): {msg}")
        }
        GridOutcome::Failed(..) | GridOutcome::Stopped(_) => {
            unreachable!("infallible uncontrolled grid can only finish or panic")
        }
    }
}

/// Split `[0, len)` into [`MORSEL_ROWS`]-sized morsels, map each range
/// through `f` on up to `threads` threads, and return the per-morsel
/// results in morsel order. Inputs shorter than one morsel never spawn.
pub fn map_morsels<T, F>(len: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(std::ops::Range<usize>) -> T + Sync,
{
    let n = len.div_ceil(MORSEL_ROWS);
    map_tasks(n, threads, |m| {
        let start = m * MORSEL_ROWS;
        f(start..(start + MORSEL_ROWS).min(len))
    })
}

/// Side-effect-only variant of [`map_morsels`].
pub fn for_each_morsel<F>(len: usize, threads: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    let _: Vec<()> = map_morsels(len, threads, f);
}

/// Fallible [`map_morsels`]: the per-morsel values in morsel order, or
/// the **first error in morsel order** — not completion order. After
/// the first failure workers stop claiming new morsels, but the
/// surfaced error is still deterministic at every thread count:
/// morsels are claimed as a monotone prefix, so the minimal failing
/// morsel is always claimed (and run to completion) before any later
/// one.
///
/// This is also the morsel engine's cancellation point: when the
/// calling thread has an ambient [`crate::lifecycle::QueryControl`]
/// (see [`crate::lifecycle::with_control`]), cancellation, deadline
/// expiry, or a sibling's captured panic stops the grid at the next
/// morsel boundary with the structured lifecycle error. A panicking
/// morsel body surfaces as `Error::Internal` carrying the payload —
/// the panic never crosses the caller's frame.
pub fn try_map_morsels<T, F>(len: usize, threads: usize, f: F) -> crate::error::Result<Vec<T>>
where
    T: Send,
    F: Fn(std::ops::Range<usize>) -> crate::error::Result<T> + Sync,
{
    let ctl = current_control();
    let n = len.div_ceil(MORSEL_ROWS);
    match run_grid(n, threads, ctl.as_ref(), |m| {
        let start = m * MORSEL_ROWS;
        f(start..(start + MORSEL_ROWS).min(len))
    }) {
        GridOutcome::Done(v) => Ok(v),
        GridOutcome::Failed(_, TaskFailure::Err(e)) => Err(e),
        GridOutcome::Failed(i, TaskFailure::Panicked(msg)) => {
            Err(Error::internal(format!("morsel worker panicked (morsel {i}): {msg}")))
        }
        GridOutcome::Stopped(e) => Err(e),
    }
}

/// Deterministic mutable-slice fan-out: split one pre-sized buffer into
/// the consecutive disjoint regions described by `extents` (region `i`
/// is `extents[i]` bytes, `split_at_mut` disjointness) and run
/// `f(region_index, region)` once per region on up to `threads` scoped
/// threads, pulled off the same atomic task counter as [`map_tasks`].
///
/// This is the write half of the zero-copy wire path: the serializer
/// precomputes every column block's exact byte length, then each task
/// encodes its column **in place** into its region — no per-task
/// scratch buffer, no second copy.
///
/// # Contract
///
/// * `extents` must tile `buf` exactly (`sum(extents) == buf.len()`);
///   anything else is a caller bug and **panics** before any task runs.
/// * Each region is owned exclusively by its task, so which thread runs
///   which region is unobservable: for a pure `f`, the buffer contents
///   afterwards are **bit-identical at every thread count**.
/// * `threads <= 1` (or a single region) runs inline with zero spawns.
pub fn for_each_slice_mut<F>(buf: &mut [u8], extents: &[usize], threads: usize, f: F)
where
    F: Fn(usize, &mut [u8]) + Sync,
{
    let total: usize = extents.iter().sum();
    assert_eq!(
        total,
        buf.len(),
        "for_each_slice_mut: extents cover {total} bytes, buffer has {}",
        buf.len()
    );
    let n = extents.len();
    let threads = threads.max(1).min(n);
    if threads <= 1 {
        let mut rest = buf;
        for (i, &e) in extents.iter().enumerate() {
            let (region, tail) = rest.split_at_mut(e);
            f(i, region);
            rest = tail;
        }
        return;
    }
    // Pre-split the buffer into disjoint regions, then let workers pull
    // region indices off a shared counter (the morsel work-stealing
    // discipline). Each slot's mutex is locked exactly once — it exists
    // only to hand the `&mut` region across threads safely.
    let mut slots: Vec<std::sync::Mutex<Option<&mut [u8]>>> = Vec::with_capacity(n);
    {
        let mut rest = buf;
        for &e in extents {
            let (region, tail) = rest.split_at_mut(e);
            slots.push(std::sync::Mutex::new(Some(region)));
            rest = tail;
        }
    }
    match run_grid(n, threads, None, |i| {
        let region = slots[i]
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .take()
            .expect("each region is taken exactly once");
        f(i, region);
        Ok(())
    }) {
        GridOutcome::Done(_) => {}
        GridOutcome::Failed(i, TaskFailure::Panicked(msg)) => {
            panic!("slice worker panicked (region {i}): {msg}")
        }
        GridOutcome::Failed(..) | GridOutcome::Stopped(_) => {
            unreachable!("infallible uncontrolled grid can only finish or panic")
        }
    }
}

/// Reassemble per-morsel chunks into one flat vector of `len` elements.
pub fn concat_chunks<T: Copy>(chunks: Vec<Vec<T>>, len: usize) -> Vec<T> {
    let mut out = Vec::with_capacity(len);
    for c in chunks {
        out.extend_from_slice(&c);
    }
    out
}

/// K-way merge of sorted runs into one sorted vector (the reassembly
/// step of the morsel-parallel sort). `le(a, b)` must mean "`a` may
/// precede `b`" and be a total preorder — on ties the element from the
/// earlier run wins, so with a total order (e.g. `(key, row)` pairs)
/// the result is the unique globally sorted sequence regardless of
/// `threads` or run boundaries.
///
/// Large inputs take a **splitter-partitioned** path: `threads - 1`
/// splitters sampled from the runs cut every run at its upper bound of
/// each splitter, giving `threads` disjoint key ranges that merge
/// concurrently on [`map_tasks`] and concatenate in range order. Every
/// element equivalent to a splitter lands left of its cut in *every*
/// run, so equal keys never straddle a range boundary and each range's
/// merge sees the same runs in the same order — the concatenation is
/// bit-identical to the serial pairwise merge, the oracle pinned in
/// the tests below. Small inputs (or `threads <= 1`) keep the
/// pairwise `log₂ k`-pass path with zero sampling overhead.
pub fn merge_runs<T, F>(runs: Vec<Vec<T>>, threads: usize, le: F) -> Vec<T>
where
    T: Copy + Send + Sync,
    F: Fn(&T, &T) -> bool + Sync,
{
    let total: usize = runs.iter().map(|r| r.len()).sum();
    let threads = threads.max(1);
    if threads == 1 || runs.len() <= 1 || total < PAR_MIN_ROWS {
        return merge_runs_pairwise(runs, threads, &le);
    }
    // Sample `threads - 1` candidates per run at evenly spaced
    // positions, order them, and take evenly spaced splitters — the
    // classic sample-sort bound: no range exceeds ~2·total/threads.
    let mut candidates: Vec<T> = Vec::new();
    for run in &runs {
        if run.is_empty() {
            continue;
        }
        for t in 1..threads {
            candidates.push(run[t * run.len() / threads]);
        }
    }
    candidates.sort_by(|a, b| {
        if le(a, b) {
            if le(b, a) {
                std::cmp::Ordering::Equal
            } else {
                std::cmp::Ordering::Less
            }
        } else {
            std::cmp::Ordering::Greater
        }
    });
    let splitters: Vec<T> = (1..threads)
        .filter_map(|i| candidates.get(i * candidates.len() / threads).copied())
        .collect();
    // Cut every run at the upper bound of each splitter (first element
    // strictly greater). Cuts are monotone per run, so the ranges
    // `[cuts[r], cuts[r+1])` tile each run exactly.
    let cuts: Vec<Vec<usize>> = runs
        .iter()
        .map(|run| {
            let mut c = Vec::with_capacity(splitters.len() + 2);
            c.push(0);
            let mut lo = 0usize;
            for s in &splitters {
                let mut hi = run.len();
                while lo < hi {
                    let mid = (lo + hi) / 2;
                    if le(&run[mid], s) {
                        lo = mid + 1;
                    } else {
                        hi = mid;
                    }
                }
                c.push(lo);
            }
            c.push(run.len());
            c
        })
        .collect();
    let nranges = splitters.len() + 1;
    let (runs_r, cuts_r, le_r) = (&runs, &cuts, &le);
    let pieces = map_tasks(nranges, threads, |r| {
        let slices: Vec<Vec<T>> = runs_r
            .iter()
            .zip(cuts_r)
            .map(|(run, c)| run[c[r]..c[r + 1]].to_vec())
            .collect();
        merge_runs_pairwise(slices, 1, le_r)
    });
    let mut out = Vec::with_capacity(total);
    for p in pieces {
        out.extend_from_slice(&p);
    }
    out
}

/// The pairwise merge behind [`merge_runs`]: runs merge pairwise in
/// run order over `log₂ k` passes, each pass fanning the pair merges
/// out on [`map_tasks`]. Tie-breaking and pairing are pure functions
/// of the run order, so the output never depends on `threads`.
fn merge_runs_pairwise<T, F>(mut runs: Vec<Vec<T>>, threads: usize, le: &F) -> Vec<T>
where
    T: Copy + Send + Sync,
    F: Fn(&T, &T) -> bool + Sync,
{
    while runs.len() > 1 {
        // An odd tail run rides through the pass by move, not copy; it
        // re-joins at the end, keeping the pairing in run order.
        let tail = if runs.len() % 2 == 1 { runs.pop() } else { None };
        let cur = &runs;
        let mut next = map_tasks(cur.len() / 2, threads, |k| {
            let (a, b) = (&cur[2 * k], &cur[2 * k + 1]);
            let mut out = Vec::with_capacity(a.len() + b.len());
            let (mut i, mut j) = (0usize, 0usize);
            while i < a.len() && j < b.len() {
                if le(&a[i], &b[j]) {
                    out.push(a[i]);
                    i += 1;
                } else {
                    out.push(b[j]);
                    j += 1;
                }
            }
            out.extend_from_slice(&a[i..]);
            out.extend_from_slice(&b[j..]);
            out
        });
        next.extend(tail);
        runs = next;
    }
    runs.pop().unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_tasks_preserves_order_across_thread_counts() {
        let want: Vec<usize> = (0..100).map(|i| i * i).collect();
        for threads in [1, 2, 7, 64] {
            assert_eq!(map_tasks(100, threads, |i| i * i), want, "threads={threads}");
        }
    }

    #[test]
    fn map_tasks_empty_and_single() {
        assert_eq!(map_tasks(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(map_tasks(1, 4, |i| i + 10), vec![10]);
    }

    #[test]
    fn try_map_morsels_surfaces_first_error_in_morsel_order() {
        let len = MORSEL_ROWS * 3;
        for threads in [1, 2, 7] {
            let ok = try_map_morsels(len, threads, |r| Ok(r.end - r.start)).unwrap();
            assert_eq!(ok.iter().sum::<usize>(), len, "threads={threads}");
            // morsels 1 and 2 both fail; morsel order (not completion
            // order) decides which error wins
            let err = try_map_morsels(len, threads, |r| {
                if r.start >= MORSEL_ROWS {
                    Err(crate::error::Error::invalid(format!("morsel at {}", r.start)))
                } else {
                    Ok(0usize)
                }
            })
            .unwrap_err();
            assert!(
                err.to_string().contains(&format!("morsel at {MORSEL_ROWS}")),
                "threads={threads}: {err}"
            );
        }
    }

    #[test]
    fn panicking_task_is_contained_and_reraised_once() {
        // The worker catches the unwind; the caller sees exactly one
        // clean panic carrying the payload — catchable, no abort.
        for threads in [1, 2, 7] {
            let r = std::panic::catch_unwind(|| {
                map_tasks(20, threads, |i| {
                    if i == 3 {
                        panic!("bad row in task 3");
                    }
                    i
                })
            });
            let p = r.expect_err("task panic must surface");
            let msg = panic_msg(p);
            assert!(msg.contains("bad row in task 3"), "threads={threads}: {msg}");
            assert!(msg.contains("morsel worker panicked"), "threads={threads}: {msg}");
        }
    }

    #[test]
    fn try_map_morsels_converts_panics_to_structured_errors() {
        let len = MORSEL_ROWS * 4;
        for threads in [1, 2, 7] {
            let err = try_map_morsels(len, threads, |r| {
                if r.start == MORSEL_ROWS * 2 {
                    panic!("kernel blew up");
                }
                Ok(r.len())
            })
            .unwrap_err();
            assert!(
                matches!(err, crate::error::Error::Internal(_)),
                "threads={threads}: {err}"
            );
            let s = err.to_string();
            assert!(s.contains("kernel blew up"), "threads={threads}: {s}");
        }
    }

    #[test]
    fn slice_fanout_contains_panics() {
        for threads in [2, 7] {
            let r = std::panic::catch_unwind(|| {
                let mut buf = vec![0u8; 64];
                let extents = vec![16usize; 4];
                for_each_slice_mut(&mut buf, &extents, threads, |i, region| {
                    if i == 2 {
                        panic!("region 2 died");
                    }
                    region.fill(1);
                });
            });
            let msg = panic_msg(r.expect_err("region panic must surface"));
            assert!(msg.contains("region 2 died"), "threads={threads}: {msg}");
        }
    }

    #[test]
    fn try_map_morsels_honors_ambient_cancellation() {
        use crate::lifecycle::{with_control, QueryControl};
        let len = MORSEL_ROWS * 3;
        for threads in [1, 2, 7] {
            let ctl = QueryControl::new(5);
            ctl.cancel();
            let err = with_control(&ctl, || {
                try_map_morsels(len, threads, |r| Ok(r.len()))
            })
            .unwrap_err();
            assert!(err.is_cancellation(), "threads={threads}: {err}");
            assert!(err.to_string().contains("rank 5"), "threads={threads}: {err}");
            // Without a control (or uncancelled) the same call succeeds
            // with the identical morsel schedule.
            let ok = try_map_morsels(len, threads, |r| Ok(r.len())).unwrap();
            assert_eq!(ok.len(), 3);
        }
    }

    #[test]
    fn try_map_morsels_honors_ambient_deadline() {
        use crate::lifecycle::{with_control, QueryControl};
        let ctl = QueryControl::new(0);
        ctl.set_deadline(std::time::Instant::now() - std::time::Duration::from_millis(1));
        let err = with_control(&ctl, || {
            try_map_morsels(MORSEL_ROWS * 2, 2, |r| Ok(r.len()))
        })
        .unwrap_err();
        assert!(
            matches!(err, crate::error::Error::DeadlineExceeded(_)),
            "{err}"
        );
    }

    #[test]
    fn morsel_boundaries_fixed_and_covering() {
        // 2.5 morsels worth of rows: ranges must tile [0, len) exactly
        // and be identical at every thread count.
        let len = MORSEL_ROWS * 2 + MORSEL_ROWS / 2;
        let serial = map_morsels(len, 1, |r| (r.start, r.end));
        assert_eq!(serial.len(), 3);
        assert_eq!(serial[0], (0, MORSEL_ROWS));
        assert_eq!(serial[2].1, len);
        for threads in [2, 7] {
            assert_eq!(map_morsels(len, threads, |r| (r.start, r.end)), serial);
        }
    }

    #[test]
    fn morsel_sums_equal_serial() {
        let len = MORSEL_ROWS + 123;
        let want: u64 = (0..len as u64).sum();
        for threads in [1, 3, 8] {
            let got: u64 = map_morsels(len, threads, |r| {
                r.map(|i| i as u64).sum::<u64>()
            })
            .into_iter()
            .sum();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn for_each_morsel_visits_every_row_once() {
        use std::sync::atomic::AtomicU64;
        let len = MORSEL_ROWS + 7;
        let sum = AtomicU64::new(0);
        for_each_morsel(len, 4, |r| {
            let s: u64 = r.map(|i| i as u64).sum();
            sum.fetch_add(s, Ordering::Relaxed);
        });
        assert_eq!(sum.into_inner(), (0..len as u64).sum::<u64>());
    }

    /// Fill region `i` with a pattern derived from the region index and
    /// in-region position — any misrouted or overlapping write changes
    /// the bytes.
    fn fill_regions(buf: &mut [u8], extents: &[usize], threads: usize) {
        for_each_slice_mut(buf, extents, threads, |i, region| {
            for (k, b) in region.iter_mut().enumerate() {
                *b = (i as u8).wrapping_mul(31).wrapping_add(k as u8);
            }
        });
    }

    #[test]
    fn slice_fanout_bit_identical_across_thread_counts() {
        // Mixed extents including empty regions and a word-boundary mix.
        let extents = [0usize, 7, 64, 1, 0, 129, 3];
        let len: usize = extents.iter().sum();
        let mut serial = vec![0u8; len];
        fill_regions(&mut serial, &extents, 1);
        // Regions tile the buffer: every byte was written by its region.
        assert_eq!(serial[0], 1u8.wrapping_mul(31)); // region 1, k = 0
        for threads in [2usize, 7, 64] {
            let mut par = vec![0xAAu8; len];
            fill_regions(&mut par, &extents, threads);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn slice_fanout_empty_and_single_region() {
        let mut empty: Vec<u8> = Vec::new();
        for_each_slice_mut(&mut empty, &[], 4, |_, _| panic!("no regions"));
        let mut one = vec![0u8; 5];
        for_each_slice_mut(&mut one, &[5], 4, |i, r| {
            assert_eq!(i, 0);
            r.fill(9);
        });
        assert_eq!(one, vec![9; 5]);
    }

    #[test]
    #[should_panic(expected = "extents cover")]
    fn slice_fanout_rejects_short_extents() {
        let mut buf = vec![0u8; 10];
        for_each_slice_mut(&mut buf, &[3, 3], 2, |_, _| {});
    }

    #[test]
    #[should_panic(expected = "extents cover")]
    fn slice_fanout_rejects_long_extents() {
        let mut buf = vec![0u8; 10];
        for_each_slice_mut(&mut buf, &[8, 8], 2, |_, _| {});
    }

    #[test]
    fn concat_chunks_flattens_in_order() {
        let chunks = vec![vec![1u32, 2], vec![], vec![3, 4, 5]];
        assert_eq!(concat_chunks(chunks, 5), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn merge_runs_equals_global_sort_at_every_thread_count() {
        // Deterministic pseudo-random runs, each individually sorted.
        let mut x = 0x12345u64;
        let mut next = move || {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (x >> 33) as u32
        };
        let mut all: Vec<u32> = (0..5000).map(|_| next() % 97).collect();
        let runs: Vec<Vec<u32>> = all
            .chunks(617)
            .map(|c| {
                let mut r = c.to_vec();
                r.sort_unstable();
                r
            })
            .collect();
        all.sort_unstable();
        for threads in [1usize, 2, 7] {
            assert_eq!(merge_runs(runs.clone(), threads, |a, b| a <= b), all);
        }
    }

    #[test]
    fn merge_runs_edge_shapes() {
        assert_eq!(merge_runs(Vec::<Vec<u8>>::new(), 4, |a, b| a <= b), Vec::<u8>::new());
        assert_eq!(merge_runs(vec![vec![1u8, 2]], 4, |a, b| a <= b), vec![1, 2]);
        // Odd run count: the unpaired tail run survives the pass intact.
        let runs = vec![vec![1u8, 9], vec![2, 3], vec![0, 5]];
        assert_eq!(merge_runs(runs, 2, |a, b| a <= b), vec![0, 1, 2, 3, 5, 9]);
    }

    /// Deterministic pseudo-random sorted runs over `key_space` keys.
    fn sorted_runs(total: usize, run_len: usize, key_space: u64, seed: u64) -> Vec<Vec<u32>> {
        let mut x = seed;
        let mut next = move || {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((x >> 33) as u64 % key_space) as u32
        };
        let all: Vec<u32> = (0..total).map(|_| next()).collect();
        all.chunks(run_len.max(1))
            .map(|c| {
                let mut r = c.to_vec();
                r.sort_unstable();
                r
            })
            .collect()
    }

    #[test]
    fn splitter_merge_equals_pairwise_oracle() {
        // Above PAR_MIN_ROWS with threads > 1 the splitter path runs;
        // the serial pairwise merge is the oracle. Duplicate-heavy
        // keyspaces force equal keys to straddle candidate positions,
        // and the empty run exercises degenerate cuts.
        for (total, key_space) in [(PAR_MIN_ROWS * 2, 3u64), (10_000, 50), (10_000, 1)] {
            let mut runs = sorted_runs(total, 700, key_space, 0xBEEF);
            runs.insert(2, Vec::new());
            let oracle = merge_runs_pairwise(runs.clone(), 1, &|a: &u32, b: &u32| a <= b);
            for threads in [2usize, 3, 7, 16] {
                assert_eq!(
                    merge_runs(runs.clone(), threads, |a, b| a <= b),
                    oracle,
                    "total={total} key_space={key_space} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn splitter_merge_is_stable_across_runs() {
        // (key, run) pairs with massive duplication: ties must resolve
        // to the earlier run on the splitter path too, at every thread
        // count — the full tie-break order is part of the contract.
        let nruns = 9;
        let per = (PAR_MIN_ROWS / 2).max(1000);
        let runs: Vec<Vec<(u32, u32)>> = (0..nruns)
            .map(|r| (0..per).map(|i| ((i / 100) as u32, r as u32)).collect())
            .collect();
        let le = |a: &(u32, u32), b: &(u32, u32)| a.0 < b.0 || (a.0 == b.0 && a.1 <= b.1);
        let oracle = merge_runs_pairwise(runs.clone(), 1, &le);
        for threads in [2usize, 7] {
            assert_eq!(merge_runs(runs.clone(), threads, le), oracle, "threads={threads}");
        }
    }

    #[test]
    fn traced_grid_emits_one_span_with_worker_busy_counters() {
        use crate::trace::{with_sink, SpanKind, TraceSink};
        let sink = TraceSink::new(1, 0);
        let got = with_sink(&sink, || map_tasks(20, 3, |i| i * 2));
        assert_eq!(got, map_tasks(20, 3, |i| i * 2), "tracing must not change results");
        let spans = sink.spans();
        let grids: Vec<_> =
            spans.iter().filter(|s| s.kind == SpanKind::Grid).collect();
        assert_eq!(grids.len(), 1, "one span per grid, not per task");
        let g = grids[0];
        assert_eq!(g.counter("tasks"), Some(20));
        assert_eq!(g.counter("threads"), Some(3));
        assert!(
            (0..3).any(|w| g.counter(&format!("w{w}_busy_ns")).is_some()),
            "at least one worker busy counter: {:?}",
            g.counters
        );
        // Disabled sink: nothing recorded, same results.
        let off = TraceSink::disabled();
        let got_off = with_sink(&off, || map_tasks(20, 3, |i| i * 2));
        assert_eq!(got_off, got);
        assert_eq!(off.span_count(), 0);
    }

    #[test]
    fn knob_roundtrip() {
        // The knob only changes speed, never results, so briefly setting
        // it is safe even with concurrently running tests.
        set_parallelism(3);
        assert_eq!(parallelism(), 3);
        set_parallelism(0);
        assert!(parallelism() >= 1);
    }
}
