//! Select — row filter by a user predicate (§II-B1).
//!
//! "Pleasingly parallel": the distributed form is exactly the local form
//! applied to each partition, no network needed.

use crate::error::Result;
use crate::table::{take::filter_table, RowRef, Table};

/// Filter rows of `t` by `pred`, preserving order.
pub fn select<F>(t: &Table, pred: F) -> Result<Table>
where
    F: Fn(RowRef<'_>) -> bool,
{
    let mask: Vec<bool> = (0..t.num_rows()).map(|i| pred(t.row(i))).collect();
    filter_table(t, &mask)
}

/// Typed fast path: filter by a predicate over an int64 column's values.
/// Null cells never match. This is the shape of the paper's Select
/// benchmark workloads (predicates over the index column).
pub fn select_i64<F>(t: &Table, col: usize, pred: F) -> Result<Table>
where
    F: Fn(i64) -> bool,
{
    let a = t
        .column(col)
        .as_i64()
        .ok_or_else(|| crate::error::Error::schema("select_i64 on non-int64 column"))?;
    let mask: Vec<bool> = (0..a.len())
        .map(|i| a.is_valid(i) && pred(a.value(i)))
        .collect();
    filter_table(t, &mask)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::table::Array;

    fn t() -> Table {
        Table::from_arrays(vec![
            ("id", Array::from_i64_opts(vec![Some(1), Some(2), None, Some(4)])),
            ("v", Array::from_f64(vec![0.1, 0.2, 0.3, 0.4])),
        ])
        .unwrap()
    }

    #[test]
    fn row_predicate() {
        let out = select(&t(), |r| r.is_valid(0)).unwrap();
        assert_eq!(out.num_rows(), 3);
    }

    #[test]
    fn typed_predicate_skips_nulls() {
        let out = select_i64(&t(), 0, |v| v >= 2).unwrap();
        assert_eq!(out.num_rows(), 2);
        assert_eq!(out.column(0).as_i64().unwrap().get(0), Some(2));
        assert_eq!(out.column(0).as_i64().unwrap().get(1), Some(4));
    }

    #[test]
    fn empty_result_keeps_schema() {
        let out = select_i64(&t(), 0, |_| false).unwrap();
        assert_eq!(out.num_rows(), 0);
        assert_eq!(out.num_columns(), 2);
    }

    #[test]
    fn wrong_type_errors() {
        assert!(select_i64(&t(), 1, |_| true).is_err());
    }
}
