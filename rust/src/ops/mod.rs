//! Local relational operators (Table I of the paper).
//!
//! Local operators work entirely on the data available to this process;
//! distributed counterparts in [`crate::dist`] compose them with the
//! AllToAll network operator (Fig. 3).

pub mod aggregate;
pub mod difference;
pub mod expr;
pub mod hash;
pub mod intersect;
pub mod join;
pub mod merge;
pub mod partition;
pub mod project;
pub(crate) mod rowset;
pub mod select;
pub mod sort;
pub mod union;

pub use aggregate::{group_by, AggFn, AggSpec};
pub use difference::difference;
pub use expr::Expr;
pub use intersect::intersect;
pub use join::{join, JoinAlgorithm, JoinConfig, JoinType};
pub use merge::merge_sorted;
pub use partition::{hash_partition, partition_indices};
pub use project::project;
pub use select::select;
pub use sort::{sort, sort_indices};
pub use union::union;
