//! Local relational operators (Table I of the paper).
//!
//! Local operators work entirely on the data available to this process;
//! distributed counterparts in [`crate::dist`] compose them with the
//! AllToAll network operator (Fig. 3).
//!
//! # Morsel-parallel execution model
//!
//! The hot operators (hash join, group-by, hash partition, row-hash
//! dedup, take materialization) run on the stdlib-only morsel engine in
//! [`parallel`]: inputs are chunked into fixed 64Ki-row morsels, key
//! and row hashes are computed **columnarly** ([`hash::hash_column`] /
//! [`hash::hash_rows`], one typed pass, no per-cell enum dispatch), and
//! scoped worker threads pull chunks off a shared counter. The thread
//! budget comes from [`parallel::parallelism`] (or the explicit `_par`
//! operator variants, or [`crate::ctx::CylonContext::parallelism`] in
//! the distributed layer).
//!
//! # Determinism contract
//!
//! Parallelism changes speed, **never results**: every operator's
//! output is bit-identical at every thread count, because morsel
//! boundaries and radix fan-outs are pure functions of the input (never
//! of the thread count) and results are reassembled in task order.
//! Orders are canonical per operator: group-by keeps first-appearance
//! key order, set operators keep first-occurrence row order, the hash
//! join emits radix-partition-major order (see the `join` module
//! docs), sort orders by `(key, original row)` — stable on duplicate
//! keys, so morsel runs merge to one unique permutation — and shuffle
//! routing stays `hash(key) % world` — the bit-exact contract shared
//! with the AOT Pallas kernel. `tests/prop_parallel.rs` pins all of
//! this at `parallelism ∈ {1, 2, 7}`; `tests/prop_sort.rs` pins the
//! sort/external-sort/dist-sort chain the same way.
//!
//! Order-based operators (sort, merge, sort-join, sample-sort routing)
//! additionally share the **typed sort-key contract** of [`sort`]:
//! the `Array` enum is resolved once at key-extraction time (u64
//! encodings / [`sort::KeyCol`] comparators), so no per-comparison
//! enum dispatch survives in any hot loop.

pub mod aggregate;
pub mod difference;
pub mod expr;
pub mod hash;
pub mod intersect;
pub mod join;
pub mod merge;
pub mod parallel;
pub mod partition;
pub mod project;
pub(crate) mod rowset;
pub mod select;
pub mod sort;
pub mod union;

pub use aggregate::{group_by, group_by_par, AggFn, AggSpec};
pub use difference::difference;
pub use expr::Expr;
pub use intersect::intersect;
pub use join::{join, join_par, JoinAlgorithm, JoinConfig, JoinType};
pub use merge::{merge_sorted, RowKey};
pub use parallel::{parallelism, set_parallelism};
pub use partition::{hash_partition, partition_indices};
pub use project::project;
pub use select::select;
pub use sort::{sort, sort_indices, sort_indices_par, sort_par};
pub use union::union;
