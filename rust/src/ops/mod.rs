//! Local relational operators (Table I of the paper).
//!
//! Local operators work entirely on the data available to this process;
//! distributed counterparts in [`crate::dist`] compose them with the
//! AllToAll network operator (Fig. 3).
//!
//! # Morsel-parallel execution model
//!
//! The hot operators (hash join, group-by, hash partition, row-hash
//! dedup, take materialization) run on the stdlib-only morsel engine in
//! [`parallel`]: inputs are chunked into fixed 64Ki-row morsels, key
//! and row hashes are computed **columnarly** ([`hash::hash_column`] /
//! [`hash::hash_rows`], one typed pass, no per-cell enum dispatch), and
//! scoped worker threads pull chunks off a shared counter. The thread
//! budget comes from [`parallel::parallelism`] (or the explicit `_par`
//! operator variants, or [`crate::ctx::CylonContext::parallelism`] in
//! the distributed layer).
//!
//! # Determinism contract
//!
//! Parallelism changes speed, **never results**: every operator's
//! output is bit-identical at every thread count, because morsel
//! boundaries and radix fan-outs are pure functions of the input (never
//! of the thread count) and results are reassembled in task order.
//! Orders are canonical per operator: group-by keeps first-appearance
//! key order, the hash join and the set operators emit
//! radix-partition-major order above [`join::RADIX_MIN_ROWS`] (the
//! serial first-occurrence order below it — see the `join` and
//! `rowset` module docs), sort orders by `(key, original row)` —
//! stable on duplicate keys, so morsel runs merge to one unique
//! permutation — and shuffle routing stays `hash(key) % world` — the
//! bit-exact contract shared with the AOT Pallas kernel.
//! `tests/prop_parallel.rs` pins all of this at `parallelism ∈ {1, 2,
//! 7}`; `tests/prop_sort.rs` pins the sort/external-sort/dist-sort
//! chain the same way; `tests/prop_plan.rs` pins that the query
//! planner ([`crate::plan`]) preserves every one of these orders.
//!
//! The size-derived choices the hash join and set operators make
//! (build side, radix fan-out) are exposed as pinned entry points
//! ([`join::join_par_pinned`], `union_radix` / `intersect_radix` /
//! `difference_radix`) so the planner's predicate pushdown can replay
//! the pre-pushdown decisions bit-for-bit.
//!
//! Order-based operators (sort, merge, sort-join, sample-sort routing)
//! additionally share the **typed sort-key contract** of [`sort`]:
//! the `Array` enum is resolved once at key-extraction time (u64
//! encodings / [`sort::KeyCol`] comparators), so no per-comparison
//! enum dispatch survives in any hot loop.

pub mod aggregate;
pub mod difference;
pub mod expr;
pub mod hash;
pub mod intersect;
pub mod join;
pub mod merge;
pub mod parallel;
pub mod partition;
pub mod project;
pub(crate) mod rowset;
pub mod select;
pub mod sort;
pub mod union;

pub use aggregate::{group_by, group_by_par, AggFn, AggSpec};
pub use difference::{difference, difference_radix};
pub use expr::Expr;
pub use intersect::{intersect, intersect_radix};
pub use join::{join, join_par, join_par_pinned, radix_fanout, JoinAlgorithm, JoinConfig, JoinType};
pub use merge::{merge_sorted, RowKey};
pub use parallel::{parallelism, set_parallelism};
pub use partition::{hash_partition, partition_indices};
pub use project::project;
pub use select::select;
pub use sort::{sort, sort_indices, sort_indices_par, sort_par};
pub use union::{distinct, union, union_radix};
