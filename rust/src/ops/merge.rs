//! Merge — combine two tables already sorted on a column into one sorted
//! table (the `Merge` local operator; also the reassembly step of a
//! sorted shuffle).

use super::sort::{cmp_cells_across, is_sorted};
use crate::error::{Error, Result};
use crate::table::{builder::TableBuilder, Table};
use std::cmp::Ordering;

/// Merge `a` and `b` (both sorted ascending on column `col`, type-equal
/// schemas) into one sorted table. Stable: ties take `a`'s rows first.
pub fn merge_sorted(a: &Table, b: &Table, col: usize) -> Result<Table> {
    if !a.schema_equals(b) {
        return Err(Error::schema("merge of schema-incompatible tables"));
    }
    if col >= a.num_columns() {
        return Err(Error::invalid(format!("merge column {col} out of range")));
    }
    debug_assert!(is_sorted(a, col) && is_sorted(b, col));
    let (ka, kb) = (a.column(col).as_ref(), b.column(col).as_ref());
    let mut out = TableBuilder::with_capacity(a.schema().clone(), a.num_rows() + b.num_rows());
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.num_rows() && j < b.num_rows() {
        match cmp_cells_across(ka, i, kb, j) {
            Ordering::Greater => {
                out.push_row(b, j)?;
                j += 1;
            }
            _ => {
                out.push_row(a, i)?;
                i += 1;
            }
        }
    }
    while i < a.num_rows() {
        out.push_row(a, i)?;
        i += 1;
    }
    while j < b.num_rows() {
        out.push_row(b, j)?;
        j += 1;
    }
    out.finish()
}

/// K-way merge of sorted partitions (distributed sort reassembly).
pub fn merge_sorted_many(parts: &[&Table], col: usize) -> Result<Table> {
    match parts.len() {
        0 => Err(Error::invalid("merge of zero tables")),
        1 => Ok(parts[0].clone()),
        _ => {
            // Tournament by pairwise merging; fine for the worker counts
            // we simulate (log W passes).
            let mut current: Vec<Table> = parts.iter().map(|t| (*t).clone()).collect();
            while current.len() > 1 {
                let mut next = Vec::with_capacity(current.len().div_ceil(2));
                for pair in current.chunks(2) {
                    if pair.len() == 2 {
                        next.push(merge_sorted(&pair[0], &pair[1], col)?);
                    } else {
                        next.push(pair[0].clone());
                    }
                }
                current = next;
            }
            Ok(current.pop().unwrap())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::sort::{is_sorted, sort};
    use crate::table::Array;

    fn t(keys: Vec<i64>) -> Table {
        let v: Vec<f64> = keys.iter().map(|k| *k as f64).collect();
        Table::from_arrays(vec![
            ("k", Array::from_i64(keys)),
            ("v", Array::from_f64(v)),
        ])
        .unwrap()
    }

    #[test]
    fn merges_two_sorted() {
        let a = t(vec![1, 3, 5]);
        let b = t(vec![2, 3, 6]);
        let m = merge_sorted(&a, &b, 0).unwrap();
        assert_eq!(m.num_rows(), 6);
        assert!(is_sorted(&m, 0));
        assert_eq!(m.column(0).as_i64().unwrap().values(), &[1, 2, 3, 3, 5, 6]);
    }

    #[test]
    fn merge_with_empty() {
        let a = t(vec![]);
        let b = t(vec![1, 2]);
        let m = merge_sorted(&a, &b, 0).unwrap();
        assert_eq!(m.num_rows(), 2);
    }

    #[test]
    fn kway_merge_equals_global_sort() {
        let parts = vec![t(vec![9, 1, 4]), t(vec![3, 7]), t(vec![2, 8, 0])];
        let sorted: Vec<Table> = parts.iter().map(|p| sort(p, 0).unwrap()).collect();
        let refs: Vec<&Table> = sorted.iter().collect();
        let m = merge_sorted_many(&refs, 0).unwrap();
        let mut all: Vec<i64> = parts
            .iter()
            .flat_map(|p| p.column(0).as_i64().unwrap().values().to_vec())
            .collect();
        all.sort();
        assert_eq!(m.column(0).as_i64().unwrap().values(), &all[..]);
    }

    #[test]
    fn schema_mismatch_rejected() {
        let a = t(vec![1]);
        let b = Table::from_arrays(vec![("k", Array::from_i64(vec![1]))]).unwrap();
        assert!(merge_sorted(&a, &b, 0).is_err());
    }
}
