//! Merge — combine sorted tables into one sorted table (the `Merge`
//! local operator; also the reassembly step of a sorted shuffle and
//! the in-memory half of the external sort's k-way merge).
//!
//! Comparison cost follows the sort engine's typed-key contract
//! ([`super::sort`]): the key column pair is resolved to a concrete
//! [`KeyCol`] once, and the merge scan runs on primitive compares —
//! no `Array`-enum dispatch per element. For streaming merges whose
//! cursors outlive any one batch (external sort), [`RowKey`] carries
//! an owned, order-preserving copy of one cell so heads compare with
//! primitive `u64`/byte comparisons.

use super::sort::{
    encode_bool, encode_f64, encode_i64, is_sorted, BoolKey, F64Key, I64Key, KeyCol, StrKey,
};
use crate::error::{Error, Result};
use crate::table::{builder::TableBuilder, Array, Table};
use std::cmp::Ordering;

/// An owned, order-preserving key for one cell. `RowKey`s of one
/// column type order exactly like [`super::sort::cmp_cells`]: `Null`
/// sorts first, primitives through the sort engine's `u64` encodings,
/// strings by UTF-8 bytes (= `char` order). Enum dispatch happens once
/// per [`RowKey::encode`]; every comparison afterwards is primitive —
/// the head-caching contract of the external sort's k-way merge.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub enum RowKey {
    /// Null cell — sorts before every valid key.
    Null,
    /// Encoded `i64` / `f64` / `bool` cell.
    U64(u64),
    /// UTF-8 bytes of a string cell.
    Bytes(Vec<u8>),
}

impl RowKey {
    /// Extract the order-preserving key of cell `row` of `a`.
    pub fn encode(a: &Array, row: usize) -> RowKey {
        if !a.is_valid(row) {
            return RowKey::Null;
        }
        match a {
            Array::Int64(p) => RowKey::U64(encode_i64(p.value(row))),
            Array::Float64(p) => RowKey::U64(encode_f64(p.value(row))),
            Array::Bool(b) => RowKey::U64(encode_bool(b.value(row))),
            Array::Utf8(s) => RowKey::Bytes(s.value(row).as_bytes().to_vec()),
        }
    }

    /// Re-encode in place. Equivalent to `*self = RowKey::encode(..)`
    /// but reuses the `Bytes` allocation across consecutive string
    /// cells — the external sort advances a cursor head once per output
    /// row, and this keeps that step malloc-free after warm-up.
    pub fn encode_into(&mut self, a: &Array, row: usize) {
        if let (Array::Utf8(s), RowKey::Bytes(buf)) = (a, &mut *self) {
            if s.is_valid(row) {
                buf.clear();
                buf.extend_from_slice(s.value(row).as_bytes());
                return;
            }
        }
        *self = RowKey::encode(a, row);
    }
}

/// Typed two-pointer merge driving the builder directly: `ka`/`kb` are
/// the typed views of `a`/`b`'s key columns. Stable: ties take `a`'s
/// rows first.
fn merge_into<K: KeyCol>(
    ka: K,
    kb: K,
    a: &Table,
    b: &Table,
    out: &mut TableBuilder,
) -> Result<()> {
    let (na, nb) = (a.num_rows(), b.num_rows());
    let (mut i, mut j) = (0usize, 0usize);
    while i < na && j < nb {
        if ka.cmp_full(i, &kb, j) == Ordering::Greater {
            out.push_row(b, j)?;
            j += 1;
        } else {
            out.push_row(a, i)?;
            i += 1;
        }
    }
    while i < na {
        out.push_row(a, i)?;
        i += 1;
    }
    while j < nb {
        out.push_row(b, j)?;
        j += 1;
    }
    Ok(())
}

/// Merge `a` and `b` (both sorted ascending on column `col`, type-equal
/// schemas) into one sorted table. Stable: ties take `a`'s rows first.
pub fn merge_sorted(a: &Table, b: &Table, col: usize) -> Result<Table> {
    if !a.schema_equals(b) {
        return Err(Error::schema("merge of schema-incompatible tables"));
    }
    if col >= a.num_columns() {
        return Err(Error::invalid(format!("merge column {col} out of range")));
    }
    debug_assert!(is_sorted(a, col) && is_sorted(b, col));
    let mut out = TableBuilder::with_capacity(a.schema().clone(), a.num_rows() + b.num_rows());
    // One enum resolution for the whole scan (schema equality above
    // guarantees the pair matches).
    match (a.column(col).as_ref(), b.column(col).as_ref()) {
        (Array::Int64(x), Array::Int64(y)) => merge_into(I64Key(x), I64Key(y), a, b, &mut out)?,
        (Array::Float64(x), Array::Float64(y)) => {
            merge_into(F64Key(x), F64Key(y), a, b, &mut out)?
        }
        (Array::Utf8(x), Array::Utf8(y)) => merge_into(StrKey(x), StrKey(y), a, b, &mut out)?,
        (Array::Bool(x), Array::Bool(y)) => merge_into(BoolKey(x), BoolKey(y), a, b, &mut out)?,
        _ => unreachable!("schema_equals guarantees matching key types"),
    }
    out.finish()
}

/// K-way merge of sorted partitions (distributed sort reassembly).
pub fn merge_sorted_many(parts: &[&Table], col: usize) -> Result<Table> {
    match parts.len() {
        0 => Err(Error::invalid("merge of zero tables")),
        1 => Ok(parts[0].clone()),
        _ => {
            // Tournament by pairwise merging; fine for the worker counts
            // we simulate (log W passes).
            let mut current: Vec<Table> = parts.iter().map(|t| (*t).clone()).collect();
            while current.len() > 1 {
                let mut next = Vec::with_capacity(current.len().div_ceil(2));
                for pair in current.chunks(2) {
                    if pair.len() == 2 {
                        next.push(merge_sorted(&pair[0], &pair[1], col)?);
                    } else {
                        next.push(pair[0].clone());
                    }
                }
                current = next;
            }
            Ok(current.pop().unwrap())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::sort::{cmp_cells_across, is_sorted, sort};
    use crate::table::Array;

    fn t(keys: Vec<i64>) -> Table {
        let v: Vec<f64> = keys.iter().map(|k| *k as f64).collect();
        Table::from_arrays(vec![
            ("k", Array::from_i64(keys)),
            ("v", Array::from_f64(v)),
        ])
        .unwrap()
    }

    #[test]
    fn merges_two_sorted() {
        let a = t(vec![1, 3, 5]);
        let b = t(vec![2, 3, 6]);
        let m = merge_sorted(&a, &b, 0).unwrap();
        assert_eq!(m.num_rows(), 6);
        assert!(is_sorted(&m, 0));
        assert_eq!(m.column(0).as_i64().unwrap().values(), &[1, 2, 3, 3, 5, 6]);
    }

    #[test]
    fn merge_with_empty() {
        let a = t(vec![]);
        let b = t(vec![1, 2]);
        let m = merge_sorted(&a, &b, 0).unwrap();
        assert_eq!(m.num_rows(), 2);
    }

    #[test]
    fn kway_merge_equals_global_sort() {
        let parts = vec![t(vec![9, 1, 4]), t(vec![3, 7]), t(vec![2, 8, 0])];
        let sorted: Vec<Table> = parts.iter().map(|p| sort(p, 0).unwrap()).collect();
        let refs: Vec<&Table> = sorted.iter().collect();
        let m = merge_sorted_many(&refs, 0).unwrap();
        let mut all: Vec<i64> = parts
            .iter()
            .flat_map(|p| p.column(0).as_i64().unwrap().values().to_vec())
            .collect();
        all.sort();
        assert_eq!(m.column(0).as_i64().unwrap().values(), &all[..]);
    }

    #[test]
    fn schema_mismatch_rejected() {
        let a = t(vec![1]);
        let b = Table::from_arrays(vec![("k", Array::from_i64(vec![1]))]).unwrap();
        assert!(merge_sorted(&a, &b, 0).is_err());
    }

    #[test]
    fn merge_is_stable_on_ties() {
        // Equal keys: all of a's rows precede b's (payload disambiguates).
        let a = Table::from_arrays(vec![
            ("k", Array::from_i64(vec![1, 1])),
            ("v", Array::from_strs(&["a0", "a1"])),
        ])
        .unwrap();
        let b = Table::from_arrays(vec![
            ("k", Array::from_i64(vec![1, 1])),
            ("v", Array::from_strs(&["b0", "b1"])),
        ])
        .unwrap();
        let m = merge_sorted(&a, &b, 0).unwrap();
        let v = m.column(1).as_utf8().unwrap();
        assert_eq!(
            (0..4).map(|i| v.value(i)).collect::<Vec<_>>(),
            vec!["a0", "a1", "b0", "b1"]
        );
    }

    #[test]
    fn merge_nulls_first_and_floats_total_order() {
        let a = Table::from_arrays(vec![(
            "k",
            Array::from_f64_opts(vec![None, Some(-0.0), Some(1.0), Some(f64::NAN)]),
        )])
        .unwrap();
        let b = Table::from_arrays(vec![(
            "k",
            Array::from_f64_opts(vec![None, Some(0.0), Some(2.0)]),
        )])
        .unwrap();
        let m = merge_sorted(&a, &b, 0).unwrap();
        assert!(is_sorted(&m, 0));
        let k = m.column(0).as_f64().unwrap();
        assert!(!k.is_valid(0) && !k.is_valid(1), "nulls first");
        // -0.0 (from a) precedes +0.0 (from b) under total order.
        assert_eq!(k.value(2).to_bits(), (-0.0f64).to_bits());
        assert_eq!(k.value(3).to_bits(), 0.0f64.to_bits());
        assert!(k.value(6).is_nan());
    }

    #[test]
    fn encode_into_matches_encode_across_variant_transitions() {
        let s = Array::Utf8(crate::table::column::Utf8Array::from_options(&[
            Some("aa"),
            None,
            Some(""),
            Some("zz"),
        ]));
        let i = Array::from_i64_opts(vec![Some(7), None]);
        let mut k = RowKey::Null;
        // Bytes reuse, Bytes→Null→Bytes, then Bytes→U64→Null fallbacks.
        for row in 0..4 {
            k.encode_into(&s, row);
            assert_eq!(k, RowKey::encode(&s, row), "utf8 row {row}");
        }
        for row in 0..2 {
            k.encode_into(&i, row);
            assert_eq!(k, RowKey::encode(&i, row), "i64 row {row}");
        }
    }

    #[test]
    fn rowkey_orders_like_cmp_cells() {
        let cols = [
            Array::from_i64_opts(vec![Some(i64::MIN), None, Some(-1), Some(0), Some(i64::MAX)]),
            Array::from_f64_opts(vec![Some(f64::NAN), Some(-0.0), None, Some(0.0), Some(-1.5)]),
            Array::from_strs(&["", "b", "aa", "a", "ba"]),
            Array::from_bools(vec![true, false, true, false, true]),
        ];
        for a in &cols {
            let keys: Vec<RowKey> = (0..a.len()).map(|i| RowKey::encode(a, i)).collect();
            for i in 0..a.len() {
                for j in 0..a.len() {
                    assert_eq!(
                        keys[i].cmp(&keys[j]),
                        cmp_cells_across(a, i, a, j),
                        "col {:?} ({i},{j})",
                        a.data_type()
                    );
                }
            }
        }
    }
}
