//! Streaming orchestrator with backpressure (DESIGN.md §3.6).
//!
//! Ingest-style pipelines (§III-D workflow integration) read batches
//! from a source, push them through a transform, and sink the results.
//! The queue between stages is **bounded**: a slow sink blocks the
//! producer instead of letting memory grow — the backpressure control
//! the paper's streaming-orchestrator substrate requires.

use crate::error::{Error, Result};
use crate::table::Table;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::time::Instant;

/// Stats from one streaming run.
#[derive(Debug, Clone, Copy, Default)]
pub struct StreamStats {
    pub batches: usize,
    pub rows: usize,
    /// Seconds producers spent blocked on a full queue (backpressure).
    pub blocked_secs: f64,
    pub elapsed_secs: f64,
}

/// A bounded-queue two-stage pipeline: `source -> [transform] -> sink`.
pub struct StreamOrchestrator {
    queue_depth: usize,
}

impl StreamOrchestrator {
    /// `queue_depth` bounds in-flight batches between stages.
    pub fn new(queue_depth: usize) -> Self {
        StreamOrchestrator { queue_depth: queue_depth.max(1) }
    }

    /// Drive `source` (returns `None` when exhausted) through
    /// `transform` into `sink`, with backpressure. The transform runs on
    /// a worker thread; source/sink run on the calling thread pair.
    pub fn run(
        &self,
        mut source: impl FnMut() -> Option<Table> + Send,
        transform: impl Fn(Table) -> Result<Table> + Send + Sync,
        mut sink: impl FnMut(Table) -> Result<()> + Send,
    ) -> Result<StreamStats> {
        let start = Instant::now();
        let (tx, rx): (SyncSender<Table>, Receiver<Table>) = sync_channel(self.queue_depth);
        let mut stats = StreamStats::default();

        let result: Result<(usize, usize, f64)> = std::thread::scope(|s| {
            // Producer thread: source -> queue (records blocked time).
            let producer = s.spawn(move || -> Result<f64> {
                let mut blocked = 0.0f64;
                while let Some(batch) = source() {
                    let mut item = batch;
                    loop {
                        match tx.try_send(item) {
                            Ok(()) => break,
                            Err(TrySendError::Full(back)) => {
                                // Backpressure: wait for the consumer.
                                let t0 = Instant::now();
                                std::thread::sleep(std::time::Duration::from_micros(100));
                                blocked += t0.elapsed().as_secs_f64();
                                item = back;
                            }
                            Err(TrySendError::Disconnected(_)) => {
                                return Err(Error::internal("stream consumer gone"));
                            }
                        }
                    }
                }
                Ok(blocked) // dropping tx closes the stream
            });

            // Consumer: queue -> transform -> sink.
            let mut batches = 0usize;
            let mut rows = 0usize;
            for batch in rx.iter() {
                let out = transform(batch)?;
                rows += out.num_rows();
                batches += 1;
                sink(out)?;
            }
            let blocked = producer.join().map_err(|_| Error::internal("producer panicked"))??;
            Ok((batches, rows, blocked))
        });

        let (batches, rows, blocked) = result?;
        stats.batches = batches;
        stats.rows = rows;
        stats.blocked_secs = blocked;
        stats.elapsed_secs = start.elapsed().as_secs_f64();
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::generator::paper_table;
    use crate::ops::select::select_i64;

    #[test]
    fn pipeline_processes_all_batches() {
        let mut n = 0;
        let source = move || {
            n += 1;
            (n <= 5).then(|| paper_table(100, 1.0, n as u64))
        };
        let mut sunk = 0usize;
        let stats = StreamOrchestrator::new(2)
            .run(
                source,
                |t| select_i64(&t, 0, |k| k % 2 == 0),
                |t| {
                    sunk += t.num_rows();
                    Ok(())
                },
            )
            .unwrap();
        assert_eq!(stats.batches, 5);
        assert_eq!(stats.rows, sunk);
        assert!(stats.rows > 0 && stats.rows < 500);
    }

    #[test]
    fn backpressure_blocks_fast_producer() {
        let mut n = 0;
        let source = move || {
            n += 1;
            (n <= 8).then(|| paper_table(10, 1.0, n as u64))
        };
        let stats = StreamOrchestrator::new(1)
            .run(
                source,
                Ok, // identity transform
                |_| {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                    Ok(())
                },
            )
            .unwrap();
        assert_eq!(stats.batches, 8);
        assert!(stats.blocked_secs > 0.0, "producer never felt backpressure");
    }

    #[test]
    fn transform_error_propagates() {
        let mut n = 0;
        let source = move || {
            n += 1;
            (n <= 3).then(|| paper_table(10, 1.0, n as u64))
        };
        let r = StreamOrchestrator::new(2).run(
            source,
            |_| Err(Error::invalid("bad batch")),
            |_| Ok(()),
        );
        assert!(r.is_err());
    }
}
