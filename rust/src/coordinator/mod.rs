//! Framework mode (§III-B): bring up workers, run BSP jobs, collect
//! metrics — the piece that lets Rylon run standalone instead of as a
//! library.
//!
//! Workers are OS threads connected by a [`crate::net::ChannelFabric`]
//! (the testbed substitute for `mpirun`). Two execution surfaces:
//!
//! * [`run_workers`] — scatter a closure to every worker, join results
//!   (the `mpirun ./app` analog; everything in `dist::` runs under it).
//! * [`StreamOrchestrator`] — a bounded-queue streaming driver with
//!   backpressure for ingest-style pipelines (DESIGN.md §3.6).

pub mod stream;

pub use stream::{StreamOrchestrator, StreamStats};

use crate::ctx::CylonContext;
use crate::error::{Error, Result};
use crate::net::CommConfig;
use crate::runtime::KernelRuntime;
use std::sync::Arc;

/// Spawn `world` workers, each with a connected [`CylonContext`], run
/// `job` on all of them, and return results ordered by rank.
///
/// Panics in workers are converted to errors on join (a worker crash
/// fails the job, it doesn't hang the leader).
pub fn run_workers<T, F>(world: usize, config: &CommConfig, job: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(&mut CylonContext) -> T + Send + Sync + Clone + 'static,
{
    try_run_workers(world, config, None, move |ctx| Ok(job(ctx))).expect("worker job failed")
}

/// Fallible variant of [`run_workers`], optionally attaching a shared
/// AOT kernel runtime to every worker's context.
pub fn try_run_workers<T, F>(
    world: usize,
    config: &CommConfig,
    runtime: Option<Arc<KernelRuntime>>,
    job: F,
) -> Result<Vec<T>>
where
    T: Send + 'static,
    F: Fn(&mut CylonContext) -> Result<T> + Send + Sync + Clone + 'static,
{
    if world == 0 {
        return Err(Error::invalid("world size 0"));
    }
    let ctxs = CylonContext::init_distributed(world, config);
    let handles: Vec<_> = ctxs
        .into_iter()
        .map(|mut ctx| {
            if let Some(rt) = &runtime {
                ctx = ctx.with_runtime(rt.clone());
            }
            let job = job.clone();
            std::thread::Builder::new()
                .name(format!("rylon-worker-{}", ctx.rank()))
                .spawn(move || {
                    // Install the context's lifecycle token as this
                    // worker's ambient control, so morsel fan-outs deep
                    // inside operators observe cancellation without
                    // threading the token through every signature. The
                    // trace sink installs the same way: jobs that call
                    // dist operators directly (no plan executor) still
                    // record spans when the context has tracing on.
                    let ctl = ctx.control().clone();
                    let sink = ctx.trace().clone();
                    crate::lifecycle::with_control(&ctl, move || {
                        crate::trace::with_sink(&sink, move || job(&mut ctx))
                    })
                })
                .expect("spawn worker")
        })
        .collect();
    handles
        .into_iter()
        .enumerate()
        .map(|(rank, h)| {
            h.join()
                .map_err(|_| Error::internal(format!("worker {rank} panicked")))?
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_workers_orders_by_rank() {
        let out = run_workers(4, &CommConfig::default(), |ctx| ctx.rank() * 10);
        assert_eq!(out, vec![0, 10, 20, 30]);
    }

    #[test]
    fn workers_communicate() {
        let out = run_workers(3, &CommConfig::default(), |ctx| {
            ctx.communicator().all_reduce_sum_u64(1).unwrap()
        });
        assert_eq!(out, vec![3, 3, 3]);
    }

    #[test]
    fn worker_error_propagates() {
        let r: Result<Vec<()>> = try_run_workers(2, &CommConfig::default(), None, |ctx| {
            if ctx.rank() == 1 {
                Err(Error::invalid("boom"))
            } else {
                Ok(())
            }
        });
        assert!(r.is_err());
    }

    #[test]
    fn zero_world_rejected() {
        let r: Result<Vec<()>> =
            try_run_workers(0, &CommConfig::default(), None, |_| Ok(()));
        assert!(r.is_err());
    }
}
