//! End-to-end distributed-operator benches on the BSP virtual clock,
//! plus ablations DESIGN.md calls out:
//!
//! * network profile sensitivity (Infiniband vs TCP — §II-D transport),
//! * skewed vs uniform keys (shuffle balance),
//! * hash vs sort join crossover,
//! * whole-row vs key hashing cost (union's row traversal penalty).

use rylon::io::generator::{skewed_table, worker_partition};
use rylon::metrics::Report;
use rylon::net::NetworkProfile;
use rylon::ops::join::{JoinAlgorithm, JoinConfig};
use rylon::sim::{sim_rylon_join, sim_rylon_union};
use rylon::table::Table;

fn chunks(total: usize, world: usize, seed: u64) -> Vec<Table> {
    (0..world)
        .map(|w| worker_partition(total, world, w, 0.9, seed))
        .collect()
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let total = if quick { 50_000 } else { 500_000 };
    let world = 16;

    // Ablation 1: transport profile (the §II-D claim that the comm layer
    // swaps without touching operators).
    let mut r1 = Report::new(
        format!("ablation: network profile, inner join, {total} rows, W={world}"),
        &["profile", "virtual_s", "comm_s"],
    );
    let l = chunks(total, world, 1);
    let r = chunks(total, world, 2);
    for p in [
        NetworkProfile::Loopback,
        NetworkProfile::Infiniband40G,
        NetworkProfile::Tcp10G,
        NetworkProfile::Tcp1G,
    ] {
        let s = sim_rylon_join(&l, &r, &JoinConfig::inner(0, 0), p, None).unwrap();
        r1.add_row(vec![
            p.name().to_string(),
            format!("{:.4}", s.virtual_secs),
            format!("{:.4}", s.phase_secs("comm")),
        ]);
    }
    print!("{}", r1.render());

    // Ablation 2: skew. A Zipf-keyed probe side (fact table) joined
    // against a uniform build side (dimension table): the hot keys all
    // route to one worker, inflating its local phase — the shuffle-skew
    // pathology. (Zipf⨝Zipf would explode the cross product, so the
    // build side stays uniform, as real dimension tables are.)
    let mut r2 = Report::new(
        format!("ablation: probe-side key skew, inner join, {total} rows, W={world}"),
        &["distribution", "virtual_s", "local_s(max worker)"],
    );
    for (name, skewed) in [("uniform", false), ("zipf", true)] {
        let probe: Vec<Table> = (0..world)
            .map(|w| {
                if skewed {
                    skewed_table(total / world, total as u64, 31 + w as u64)
                } else {
                    worker_partition(total, world, w, 0.9, 31)
                }
            })
            .collect();
        let build = chunks(total, world, 47); // uniform dimension side
        let s = sim_rylon_join(
            &build,
            &probe,
            &JoinConfig::inner(0, 0),
            NetworkProfile::Infiniband40G,
            None,
        )
        .unwrap();
        r2.add_row(vec![
            name.to_string(),
            format!("{:.4}", s.virtual_secs),
            format!("{:.4}", s.phase_secs("local")),
        ]);
    }
    print!("{}", r2.render());

    // Ablation 3: hash vs sort join across sizes (crossover check).
    let mut r3 = Report::new(
        "ablation: hash vs sort join (local), time (s)",
        &["rows", "hash", "sort"],
    );
    for exp in [14, 16, 18] {
        let n = 1usize << exp;
        let a = rylon::io::generator::paper_table(n, 0.9, 7);
        let b = rylon::io::generator::paper_table(n, 0.9, 8);
        let th = rylon::metrics::measure(3, 1, || {
            let t0 = std::time::Instant::now();
            std::hint::black_box(
                rylon::ops::join::join(
                    &a,
                    &b,
                    &JoinConfig::inner(0, 0).with_algorithm(JoinAlgorithm::Hash),
                )
                .unwrap()
                .num_rows(),
            );
            t0.elapsed().as_secs_f64()
        });
        let ts = rylon::metrics::measure(3, 1, || {
            let t0 = std::time::Instant::now();
            std::hint::black_box(
                rylon::ops::join::join(
                    &a,
                    &b,
                    &JoinConfig::inner(0, 0).with_algorithm(JoinAlgorithm::Sort),
                )
                .unwrap()
                .num_rows(),
            );
            t0.elapsed().as_secs_f64()
        });
        r3.add_row(vec![
            n.to_string(),
            format!("{:.4}", th.median_secs),
            format!("{:.4}", ts.median_secs),
        ]);
    }
    print!("{}", r3.render());

    // Ablation 4: union's whole-row traversal vs join's key-column work
    // (the paper's §IV-B observation).
    let mut r4 = Report::new(
        format!("ablation: key-shuffle join vs row-shuffle union, {total} rows, W={world}"),
        &["op", "virtual_s", "partition_s"],
    );
    let sj =
        sim_rylon_join(&l, &r, &JoinConfig::inner(0, 0), NetworkProfile::Infiniband40G, None)
            .unwrap();
    let su = sim_rylon_union(&l, &r, NetworkProfile::Infiniband40G).unwrap();
    r4.add_row(vec![
        "join(key hash)".into(),
        format!("{:.4}", sj.virtual_secs),
        format!("{:.4}", sj.phase_secs("partition")),
    ]);
    r4.add_row(vec![
        "union(row hash)".into(),
        format!("{:.4}", su.virtual_secs),
        format!("{:.4}", su.phase_secs("partition")),
    ]);
    print!("{}", r4.render());
}
