//! Microbenchmarks of the local operators (the per-worker kernels every
//! distributed op is built from).
//!
//! criterion is not vendored in this offline image; `rylon::metrics::
//! measure` (median of N timed runs after warmup) fills in. Run with
//! `cargo bench --bench local_ops`.

use rylon::io::generator::paper_table;
use rylon::metrics::{measure, Report};
use rylon::ops::aggregate::{group_by_par, AggFn, AggSpec};
use rylon::ops::join::{join, join_par, JoinAlgorithm, JoinConfig};
use rylon::ops::partition::hash_partition;
use rylon::ops::select::select_i64;
use rylon::ops::sort::sort;
use rylon::ops::union::union;
use std::hint::black_box;
use std::time::Instant;

fn bench<T>(runs: usize, mut f: impl FnMut() -> T) -> f64 {
    measure(runs, 1, || {
        let t0 = Instant::now();
        black_box(f());
        t0.elapsed().as_secs_f64()
    })
    .median_secs
}

fn main() {
    let n = if std::env::args().any(|a| a == "--quick") {
        50_000
    } else {
        500_000
    };
    let runs = 5;
    let l = paper_table(n, 0.9, 1);
    let r = paper_table(n, 0.9, 2);

    let mut report = Report::new(
        format!("local operator microbench, n = {n} rows/relation"),
        &["op", "median_s", "M rows/s"],
    );
    let mut add = |name: &str, secs: f64, rows: usize| {
        report.add_row(vec![
            name.to_string(),
            format!("{secs:.4}"),
            format!("{:.1}", rows as f64 / secs / 1e6),
        ]);
    };

    add("select (k % 2)", bench(runs, || select_i64(&l, 0, |k| k % 2 == 0).unwrap()), n);
    add("project [0,2]", bench(runs, || rylon::ops::project::project(&l, &[0, 2]).unwrap()), n);
    add("sort by key", bench(runs, || sort(&l, 0).unwrap()), n);
    add(
        "hash_partition p=16",
        bench(runs, || hash_partition(&l, 0, 16).unwrap()),
        n,
    );
    add(
        "hash join inner",
        bench(runs, || {
            join(&l, &r, &JoinConfig::inner(0, 0).with_algorithm(JoinAlgorithm::Hash)).unwrap()
        }),
        2 * n,
    );
    add(
        "sort join inner",
        bench(runs, || {
            join(&l, &r, &JoinConfig::inner(0, 0).with_algorithm(JoinAlgorithm::Sort)).unwrap()
        }),
        2 * n,
    );
    add("union distinct", bench(runs, || union(&l, &r).unwrap()), 2 * n);
    // Morsel-parallel thread sweep (same canonical output at every
    // thread count — only the wall clock moves).
    let cfg = JoinConfig::inner(0, 0).with_algorithm(JoinAlgorithm::Hash);
    let aggs = [AggSpec::new(AggFn::Sum, 1), AggSpec::new(AggFn::Mean, 2)];
    for threads in [1usize, 2, 4] {
        add(
            &format!("hash join inner (t={threads})"),
            bench(runs, || join_par(&l, &r, &cfg, threads).unwrap()),
            2 * n,
        );
        add(
            &format!("group-by sum+mean (t={threads})"),
            bench(runs, || group_by_par(&l, 0, &aggs, threads).unwrap()),
            n,
        );
    }
    add(
        "serialize+deserialize",
        bench(runs, || {
            let b = rylon::net::serialize::serialize_table(&l);
            rylon::net::serialize::deserialize_table(&b).unwrap()
        }),
        n,
    );

    print!("{}", report.render());
}
