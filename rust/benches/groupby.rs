//! GroupBy ablation: two-phase (partial-agg → shuffle partials) vs
//! naive (shuffle raw rows → aggregate), on the BSP virtual clock.
//! Quantifies the pre-aggregation design choice DESIGN.md calls out —
//! the win grows as keys repeat (low key cardinality).

use rylon::io::generator::worker_partition;
use rylon::metrics::Report;
use rylon::net::serialize::serialize_table;
use rylon::net::NetworkProfile;
use rylon::ops::aggregate::{group_by, group_by_partial, merge_partials, AggFn, AggSpec};
use rylon::ops::partition::{partition_by_ids, partition_ids_by_key};
use rylon::table::{take::concat_tables, Table};
use std::time::Instant;

/// Naive plan: shuffle raw rows by key, aggregate per worker.
fn naive(chunks: &[Table], aggs: &[AggSpec], profile: NetworkProfile) -> (f64, usize) {
    let world = chunks.len();
    let (alpha, beta) = profile.alpha_beta();
    let mut part_secs: Vec<f64> = Vec::new();
    let mut routed: Vec<Vec<Table>> = (0..world).map(|_| Vec::new()).collect();
    let mut bytes = vec![0u64; world];
    for c in chunks {
        let t0 = Instant::now();
        let ids = partition_ids_by_key(c, 0, world).unwrap();
        let parts = partition_by_ids(c, &ids, world).unwrap();
        for (d, p) in parts.into_iter().enumerate() {
            bytes[d] += serialize_table(&p).len() as u64;
            routed[d].push(p);
        }
        part_secs.push(t0.elapsed().as_secs_f64());
    }
    let comm = bytes
        .iter()
        .map(|&b| alpha * (world - 1) as f64 + b as f64 * beta)
        .fold(0.0, f64::max);
    let mut local = 0.0f64;
    let mut rows = 0;
    for parts in &routed {
        let t0 = Instant::now();
        let refs: Vec<&Table> = parts.iter().collect();
        let merged = concat_tables(&refs).unwrap();
        let out = group_by(&merged, 0, aggs).unwrap();
        rows += out.num_rows();
        local = local.max(t0.elapsed().as_secs_f64());
    }
    (part_secs.iter().fold(0.0f64, |a, &b| a.max(b)) + comm + local, rows)
}

/// Two-phase plan: partial agg locally, shuffle tiny partials, merge.
fn two_phase(chunks: &[Table], aggs: &[AggSpec], profile: NetworkProfile) -> (f64, usize) {
    let world = chunks.len();
    let (alpha, beta) = profile.alpha_beta();
    let mut pre_secs: Vec<f64> = Vec::new();
    let mut routed: Vec<Vec<Table>> = (0..world).map(|_| Vec::new()).collect();
    let mut bytes = vec![0u64; world];
    for c in chunks {
        let t0 = Instant::now();
        let partial = group_by_partial(c, 0, aggs).unwrap();
        let ids = partition_ids_by_key(&partial, 0, world).unwrap();
        let parts = partition_by_ids(&partial, &ids, world).unwrap();
        for (d, p) in parts.into_iter().enumerate() {
            bytes[d] += serialize_table(&p).len() as u64;
            routed[d].push(p);
        }
        pre_secs.push(t0.elapsed().as_secs_f64());
    }
    let comm = bytes
        .iter()
        .map(|&b| alpha * (world - 1) as f64 + b as f64 * beta)
        .fold(0.0, f64::max);
    let funcs: Vec<AggFn> = aggs.iter().map(|a| a.func).collect();
    let mut local = 0.0f64;
    let mut rows = 0;
    for parts in &routed {
        let t0 = Instant::now();
        let refs: Vec<&Table> = parts.iter().collect();
        let merged = concat_tables(&refs).unwrap();
        let out = merge_partials(&merged, &funcs).unwrap();
        rows += out.num_rows();
        local = local.max(t0.elapsed().as_secs_f64());
    }
    (pre_secs.iter().fold(0.0f64, |a, &b| a.max(b)) + comm + local, rows)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let total = if quick { 40_000 } else { 400_000 };
    let world = 16;
    let aggs = [AggSpec::new(AggFn::Sum, 1), AggSpec::new(AggFn::Mean, 2)];
    let mut report = Report::new(
        format!("groupby ablation: two-phase vs naive shuffle, {total} rows, W={world}, tcp-10g"),
        &["key_density", "naive_s", "two_phase_s", "speedup", "groups"],
    );
    // density = distinct-key fraction; low density ⇒ heavy duplication
    for density in [0.001, 0.01, 0.1, 0.9] {
        let chunks: Vec<Table> = (0..world)
            .map(|w| worker_partition(total, world, w, density, 0x6B))
            .collect();
        let (tn, _) = naive(&chunks, &aggs, NetworkProfile::Tcp10G);
        let (tp, groups) = two_phase(&chunks, &aggs, NetworkProfile::Tcp10G);
        report.add_row(vec![
            format!("{density}"),
            format!("{tn:.4}"),
            format!("{tp:.4}"),
            format!("{:.2}x", tn / tp),
            groups.to_string(),
        ]);
    }
    print!("{}", report.render());
}
