//! AOT kernel (PJRT) vs native hash-partition bench — quantifies what
//! the JAX/Pallas artifact costs/saves on the shuffle hot path, per
//! block size. Skips gracefully when artifacts are absent.

use rylon::metrics::{measure, Report};
use rylon::ops::hash::hash_i64;
use rylon::runtime::KernelRuntime;
use std::hint::black_box;
use std::time::Instant;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let dir = KernelRuntime::artifacts_dir();
    let rt = match KernelRuntime::load(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            println!("runtime_kernel bench skipped: {e}");
            return;
        }
    };
    let sizes: &[usize] = if quick {
        &[16_384, 100_000]
    } else {
        &[16_384, 65_536, 262_144, 1_000_000]
    };
    let nparts = 32u32;
    let mut report = Report::new(
        "AOT PJRT kernel vs native hash-partition (nparts=32)",
        &["rows", "native_s", "kernel_s", "kernel/native", "M keys/s (kernel)"],
    );
    for &n in sizes {
        let keys: Vec<i64> = (0..n as u64)
            .map(|i| i.wrapping_mul(0x9e37_79b9_7f4a_7c15) as i64)
            .collect();
        let native = measure(5, 1, || {
            let t0 = Instant::now();
            let ids: Vec<u32> = keys.iter().map(|&k| hash_i64(k) % nparts).collect();
            black_box(ids.len());
            t0.elapsed().as_secs_f64()
        });
        let kernel = measure(5, 1, || {
            let t0 = Instant::now();
            let ids = rt.hash_partition_ids(&keys, nparts).expect("kernel");
            black_box(ids.len());
            t0.elapsed().as_secs_f64()
        });
        // Sanity: identical routing.
        let ids = rt.hash_partition_ids(&keys, nparts).unwrap();
        for (k, id) in keys.iter().zip(&ids) {
            assert_eq!(hash_i64(*k) % nparts, *id);
        }
        report.add_row(vec![
            n.to_string(),
            format!("{:.5}", native.median_secs),
            format!("{:.5}", kernel.median_secs),
            format!("{:.2}x", kernel.median_secs / native.median_secs),
            format!("{:.1}", n as f64 / kernel.median_secs / 1e6),
        ]);
    }
    print!("{}", report.render());
    let stats = rt.stats().unwrap();
    println!(
        "kernel calls: {}, rows hashed: {}, kernel time: {:.3}s",
        stats.kernel_calls, stats.rows_hashed, stats.kernel_secs
    );
}
