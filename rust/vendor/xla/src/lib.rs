//! Offline PJRT shim — the subset of the `xla` crate surface that
//! `rylon::runtime` consumes, implemented without any native XLA
//! libraries so the workspace builds on machines with no network and no
//! PJRT plugin.
//!
//! The testbed image does not ship the real `xla` crate (it links
//! libxla via FFI and needs a download at build time). The AOT
//! artifacts rylon compiles through this interface are all instances of
//! **one** computation — the blocked hash-partition kernel lowered from
//! `python/compile/kernels/hash.py`:
//!
//! ```text
//! ids[i] = fmix32( fmix32(hi[i]) ^ lo[i] ) % nparts
//! ```
//!
//! so instead of a general HLO interpreter, [`PjRtLoadedExecutable`]
//! executes exactly that contract. The artifact file is still read and
//! sanity-checked (it must exist and be non-empty), which preserves the
//! shape of the real pipeline: lower with JAX at build time, load and
//! execute at request time, and keep bit-identical routing with the
//! native fallback (`rylon::ops::hash::hash_i64`) — the property the
//! golden-vector tests pin. Swapping this shim back for the real crate
//! is a one-line Cargo change; `rylon::runtime` compiles against either.

use std::fmt;

/// Error type matching the real crate's role: anything `Display`able.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Self {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// murmur3 fmix32 — must stay bit-identical to
/// `rylon::ops::hash::fmix32` and `kernels/hash.py::_fmix32`.
#[inline(always)]
fn fmix32(mut h: u32) -> u32 {
    h ^= h >> 16;
    h = h.wrapping_mul(0x85eb_ca6b);
    h ^= h >> 13;
    h = h.wrapping_mul(0xc2b2_ae35);
    h ^= h >> 16;
    h
}

/// Element types a [`Literal`] can hold. Only `u32` is needed by the
/// hash-partition artifact (key halves in, partition ids out).
pub trait NativeElem: Copy {
    fn into_u32(self) -> u32;
    fn from_u32(v: u32) -> Self;
}

impl NativeElem for u32 {
    fn into_u32(self) -> u32 {
        self
    }
    fn from_u32(v: u32) -> Self {
        v
    }
}

/// A host-side value: rank-1 u32 buffer, u32 scalar, or tuple.
#[derive(Debug, Clone)]
pub enum Literal {
    Vec1(Vec<u32>),
    Scalar(u32),
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeElem>(v: &[T]) -> Literal {
        Literal::Vec1(v.iter().map(|x| x.into_u32()).collect())
    }

    /// Scalar literal.
    pub fn scalar<T: NativeElem>(v: T) -> Literal {
        Literal::Scalar(v.into_u32())
    }

    /// Unwrap a 1-element tuple (the artifact returns `(ids,)`).
    pub fn to_tuple1(self) -> Result<Literal> {
        match self {
            Literal::Tuple(mut elems) if elems.len() == 1 => Ok(elems.remove(0)),
            other => Err(Error::new(format!("expected 1-tuple, got {other:?}"))),
        }
    }

    /// Copy out the element buffer.
    pub fn to_vec<T: NativeElem>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Vec1(v) => Ok(v.iter().map(|&x| T::from_u32(x)).collect()),
            Literal::Scalar(s) => Ok(vec![T::from_u32(*s)]),
            Literal::Tuple(_) => Err(Error::new("to_vec on a tuple literal")),
        }
    }
}

/// Parsed artifact. The shim validates the file exists and is
/// non-empty; the computation itself is the fixed kernel contract.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("read {path}: {e}")))?;
        if text.trim().is_empty() {
            return Err(Error::new(format!("empty HLO artifact {path}")));
        }
        Ok(HloModuleProto { text })
    }
}

/// A computation handle built from a parsed module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _proto: proto.clone() }
    }
}

/// Device-side buffer handle (host memory here).
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// "Compiled" executable: runs the hash-partition contract.
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable {
    _computation: XlaComputation,
}

impl PjRtLoadedExecutable {
    /// Execute over `(lo, hi, nparts)` and return the PJRT result
    /// shape: one replica, one output buffer holding `(ids,)`.
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        if args.len() != 3 {
            return Err(Error::new(format!(
                "hash_partition artifact takes 3 operands, got {}",
                args.len()
            )));
        }
        let lo = match args[0].borrow() {
            Literal::Vec1(v) => v,
            other => return Err(Error::new(format!("operand 0 must be u32[n], got {other:?}"))),
        };
        let hi = match args[1].borrow() {
            Literal::Vec1(v) => v,
            other => return Err(Error::new(format!("operand 1 must be u32[n], got {other:?}"))),
        };
        let nparts = match args[2].borrow() {
            Literal::Scalar(s) => *s,
            Literal::Vec1(v) if v.len() == 1 => v[0],
            other => return Err(Error::new(format!("operand 2 must be u32, got {other:?}"))),
        };
        if lo.len() != hi.len() {
            return Err(Error::new(format!(
                "operand shape mismatch: lo[{}] vs hi[{}]",
                lo.len(),
                hi.len()
            )));
        }
        if nparts == 0 {
            return Err(Error::new("nparts must be > 0"));
        }
        let ids: Vec<u32> = lo
            .iter()
            .zip(hi)
            .map(|(&l, &h)| fmix32(fmix32(h) ^ l) % nparts)
            .collect();
        Ok(vec![vec![PjRtBuffer { literal: Literal::Tuple(vec![Literal::Vec1(ids)]) }]])
    }
}

/// Client handle. The CPU "device" is the host.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable { _computation: computation.clone() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(keys: &[(u32, u32)], nparts: u32) -> Vec<u32> {
        let lo: Vec<u32> = keys.iter().map(|k| k.0).collect();
        let hi: Vec<u32> = keys.iter().map(|k| k.1).collect();
        let client = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto { text: "HloModule hash_partition".into() };
        let exe = client.compile(&XlaComputation::from_proto(&proto)).unwrap();
        let out = exe
            .execute::<Literal>(&[
                Literal::vec1(&lo),
                Literal::vec1(&hi),
                Literal::scalar(nparts),
            ])
            .unwrap();
        out[0][0]
            .to_literal_sync()
            .unwrap()
            .to_tuple1()
            .unwrap()
            .to_vec::<u32>()
            .unwrap()
    }

    #[test]
    fn executes_hash_partition_contract() {
        // hash(0) == 0, and fmix32(1) is the pinned murmur3 constant.
        let ids = run(&[(0, 0), (1, 0)], 1 << 30);
        assert_eq!(ids[0], 0);
        assert_eq!(ids[1], 0x514e_28b7 % (1 << 30));
    }

    #[test]
    fn ids_bounded_by_nparts() {
        let keys: Vec<(u32, u32)> = (0..1000u32).map(|i| (i, i.wrapping_mul(77))).collect();
        for nparts in [1, 2, 7, 32] {
            let ids = run(&keys, nparts);
            assert!(ids.iter().all(|&id| id < nparts));
        }
    }

    #[test]
    fn rejects_bad_operands() {
        let client = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto { text: "x".into() };
        let exe = client.compile(&XlaComputation::from_proto(&proto)).unwrap();
        assert!(exe.execute::<Literal>(&[Literal::scalar(1u32)]).is_err());
        assert!(exe
            .execute::<Literal>(&[
                Literal::vec1(&[1u32]),
                Literal::vec1(&[1u32, 2]),
                Literal::scalar(3u32),
            ])
            .is_err());
        assert!(exe
            .execute::<Literal>(&[
                Literal::vec1(&[1u32]),
                Literal::vec1(&[1u32]),
                Literal::scalar(0u32),
            ])
            .is_err());
    }

    #[test]
    fn missing_artifact_file_errors() {
        assert!(HloModuleProto::from_text_file("/no/such/artifact.hlo.txt").is_err());
    }
}
