//! Property tests: hash-partition is a permutation-partition (disjoint
//! cover with consistent routing) and the wire format round-trips any
//! table — the two invariants the shuffle's correctness rests on.

use rylon::io::generator::{random_table, SplitMix64};
use rylon::net::serialize::{deserialize_table, serialize_table};
use rylon::ops::hash::hash_row;
use rylon::ops::partition::{hash_partition, hash_partition_rows, partition_ids_by_key};
use rylon::table::pretty::cell_to_string;
use rylon::table::Table;
use std::collections::BTreeMap;

fn row_multiset(t: &Table) -> BTreeMap<String, usize> {
    let mut m = BTreeMap::new();
    for r in 0..t.num_rows() {
        let key = (0..t.num_columns())
            .map(|c| cell_to_string(t.column(c), r))
            .collect::<Vec<_>>()
            .join("\u{1}");
        *m.entry(key).or_insert(0) += 1;
    }
    m
}

fn merge(ms: Vec<BTreeMap<String, usize>>) -> BTreeMap<String, usize> {
    let mut out = BTreeMap::new();
    for m in ms {
        for (k, v) in m {
            *out.entry(k).or_insert(0) += v;
        }
    }
    out
}

#[test]
fn key_partition_is_disjoint_cover() {
    let mut rng = SplitMix64::new(0x9A27);
    for _ in 0..25 {
        let t = random_table(rng.next_below(200) as usize, rng.next_u64());
        let p = rng.next_below(15) as usize + 1;
        let parts = hash_partition(&t, 0, p).unwrap();
        assert_eq!(parts.len(), p);
        // multiset of all partition rows == multiset of input rows
        assert_eq!(
            merge(parts.iter().map(row_multiset).collect()),
            row_multiset(&t)
        );
        // routing is a pure function of the key
        let ids = partition_ids_by_key(&t, 0, p).unwrap();
        let ids2 = partition_ids_by_key(&t, 0, p).unwrap();
        assert_eq!(ids, ids2);
    }
}

#[test]
fn row_partition_is_disjoint_cover_with_consistent_routing() {
    let mut rng = SplitMix64::new(0x9B38);
    for _ in 0..15 {
        let t = random_table(rng.next_below(150) as usize, rng.next_u64());
        let p = rng.next_below(7) as usize + 1;
        let parts = hash_partition_rows(&t, p).unwrap();
        assert_eq!(
            merge(parts.iter().map(row_multiset).collect()),
            row_multiset(&t)
        );
        for (pid, part) in parts.iter().enumerate() {
            for r in 0..part.num_rows() {
                assert_eq!(hash_row(part, r) as usize % p, pid);
            }
        }
    }
}

#[test]
fn wire_roundtrip_random_tables() {
    let mut rng = SplitMix64::new(0x3172);
    for case in 0..40 {
        let t = random_table(rng.next_below(300) as usize, rng.next_u64());
        let bytes = serialize_table(&t);
        let back = deserialize_table(&bytes).unwrap();
        assert!(t.data_equals(&back), "case {case}: roundtrip mismatch");
        assert_eq!(t.schema(), back.schema(), "case {case}: schema mismatch");
    }
}

#[test]
fn wire_rejects_random_mutations() {
    // Flipping a byte anywhere must never panic: either clean error or
    // (rarely, e.g. float payload bits) a different but valid table.
    let mut rng = SplitMix64::new(0x0BAD);
    let t = random_table(64, 0xFEED);
    let bytes = serialize_table(&t);
    for _ in 0..200 {
        let mut corrupted = bytes.clone();
        let pos = rng.next_below(corrupted.len() as u64) as usize;
        corrupted[pos] ^= 1 << rng.next_below(8);
        let _ = deserialize_table(&corrupted); // must not panic
    }
}

#[test]
fn wire_rejects_random_truncations() {
    let mut rng = SplitMix64::new(0x7123);
    let t = random_table(128, 0xBEEF);
    let bytes = serialize_table(&t);
    for _ in 0..50 {
        let cut = rng.next_below(bytes.len() as u64 - 1) as usize;
        assert!(
            deserialize_table(&bytes[..cut]).is_err(),
            "truncation at {cut} must error"
        );
    }
}
