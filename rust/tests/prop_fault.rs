//! Fault-matrix property tests for the fault-tolerant communicator:
//! under any seeded schedule of *retryable* faults (drops, corruption,
//! delays) the reliable transport keeps every distributed operator
//! bit-identical to the fault-free oracle, at world 1 and 3 and at
//! threads 1/2/7; a *fatal* fault (injected disconnect) surfaces as a
//! structured Comm error on every rank within the timeout — never a
//! hang, never a panic. Schedules are pure functions of their seed, so
//! every failing case in this file replays exactly.

use rylon::coordinator::run_workers;
use rylon::error::Error;
use rylon::io::generator::random_table;
use rylon::net::{CommConfig, FaultPlan, RetryConfig};
use rylon::ops::join::JoinConfig;
use rylon::table::Table;
use std::time::{Duration, Instant};

const THREADS: [usize; 3] = [1, 2, 7];

/// Reliability on, fast retries, generous recv deadline: retryable
/// schedules must converge well before it.
fn reliable(plan: FaultPlan) -> CommConfig {
    CommConfig::default()
        .with_faults(plan)
        .with_reliability(true)
        .with_retry(RetryConfig::aggressive())
        .with_recv_timeout(Duration::from_secs(20))
}

/// The retryable schedules of the matrix. Default streak cap (2)
/// bounds every run: at most two consecutive injected faults per link
/// before a delivery is forced through.
fn retryable_schedules() -> Vec<(&'static str, FaultPlan)> {
    vec![
        ("drops", FaultPlan::new(0xFA01).with_drops(700)),
        ("corruption", FaultPlan::new(0xFA02).with_corruption(500)),
        ("delays", FaultPlan::new(0xFA03).with_delays(600)),
        (
            "mixed",
            FaultPlan::new(0xFA04).with_drops(300).with_corruption(200).with_delays(200),
        ),
    ]
}

fn run_shuffle(world: usize, threads: usize, config: &CommConfig) -> Vec<Table> {
    run_workers(world, config, move |ctx| {
        ctx.set_parallelism(threads);
        let t = random_table(40, 0xBEE + ctx.rank() as u64);
        rylon::dist::shuffle(ctx, &t, 0).unwrap().0
    })
}

#[test]
fn retryable_schedules_keep_shuffles_bit_identical() {
    for world in [1usize, 3] {
        let oracle = run_shuffle(world, 1, &CommConfig::default());
        for (label, plan) in retryable_schedules() {
            for threads in THREADS {
                let got = run_shuffle(world, threads, &reliable(plan.clone()));
                for (rank, (g, w)) in got.iter().zip(&oracle).enumerate() {
                    assert!(
                        g.data_equals(w),
                        "{label}: world={world} threads={threads} rank={rank} diverged"
                    );
                }
            }
        }
    }
}

#[test]
fn retryable_schedules_keep_joins_bit_identical() {
    // dist_join chains two shuffles plus collectives — the faults hit
    // every superstep, the output must not care.
    let world = 3;
    let run = |config: &CommConfig, threads: usize| -> Vec<Table> {
        run_workers(world, config, move |ctx| {
            ctx.set_parallelism(threads);
            let l = random_table(35, 0x10 + ctx.rank() as u64);
            let r = random_table(35, 0x20 + ctx.rank() as u64);
            rylon::dist::dist_join(ctx, &l, &r, &JoinConfig::inner(0, 0)).unwrap().0
        })
    };
    let oracle = run(&CommConfig::default(), 1);
    let plan = FaultPlan::new(0xFA05).with_drops(350).with_corruption(250).with_delays(150);
    for threads in THREADS {
        let got = run(&reliable(plan.clone()), threads);
        for (rank, (g, w)) in got.iter().zip(&oracle).enumerate() {
            assert!(g.data_equals(w), "threads={threads} rank={rank} diverged");
        }
    }
}

#[test]
fn disconnect_surfaces_structured_errors_on_every_rank() {
    // Rank 1 severs after its first transport op: it must fail itself
    // with a fatal error, and every other rank must get a structured
    // Comm error (timeout or dead-peer) within the deadline — no hang.
    let config = CommConfig::default()
        .with_faults(FaultPlan::new(0xFA06).with_disconnect(1, 0))
        .with_reliability(true)
        .with_retry(RetryConfig::aggressive())
        .with_recv_timeout(Duration::from_millis(800));
    let start = Instant::now();
    let errs: Vec<Option<Error>> = run_workers(3, &config, move |ctx| {
        let t = random_table(30, 3 + ctx.rank() as u64);
        rylon::dist::shuffle(ctx, &t, 0).err()
    });
    assert!(
        start.elapsed() < Duration::from_secs(15),
        "fatal schedule took {:?} — the job may be hanging on recovery",
        start.elapsed()
    );
    for (rank, e) in errs.iter().enumerate() {
        let e = e.as_ref().unwrap_or_else(|| panic!("rank {rank} should have failed"));
        assert!(matches!(e, Error::Comm(_)), "rank {rank}: unstructured error {e}");
        assert!(!e.is_retryable(), "rank {rank}: disconnects are fatal, got {e}");
    }
}

#[test]
fn disconnect_at_world_one_fails_its_own_rank() {
    // World 1 has no wire traffic inside the operators (self parts
    // loop back), so the fatal path is pinned at the transport level:
    // the severed endpoint fails its own next op, structurally.
    use rylon::net::{wrap_transport, ChannelFabric, Transport};
    let config =
        CommConfig::default().with_faults(FaultPlan::new(0xFA07).with_disconnect(0, 0));
    let mut fabric = ChannelFabric::new(1);
    let mut t = wrap_transport(Box::new(fabric.pop().unwrap()), &config);
    let e = t.send(0, 1, b"x".to_vec()).expect_err("the severed rank must fail");
    assert!(matches!(e, Error::Comm(_)), "unstructured error {e}");
    assert!(!e.is_retryable());
    assert_eq!(e.comm_peer(), None, "a self-halt names no peer: {e}");
}

#[test]
fn schedules_replay_identically_from_their_seed() {
    // The schedule is a pure function of (seed, src, dst, tag, seq) —
    // no clocks, no global state — so a faulty run replays exactly.
    let mk = |seed: u64| FaultPlan::new(seed).with_drops(400).with_corruption(300);
    let grid = |p: &FaultPlan| {
        let mut v = Vec::new();
        for src in 0..3 {
            for dst in 0..3 {
                for tag in [0u64, 7, 1 << 32] {
                    for seq in 0..50 {
                        v.push(p.decide(src, dst, tag, seq));
                    }
                }
            }
        }
        v
    };
    let plan = mk(0x5EED);
    assert_eq!(grid(&plan), grid(&plan.clone()));
    assert_ne!(grid(&plan), grid(&mk(0x5EEE)), "seed must matter");

    // And end to end: the same seeded faulty job twice gives the same
    // per-rank tables (both equal to the oracle, transitively).
    let config = reliable(mk(0x5EED));
    let a = run_shuffle(3, 2, &config);
    let b = run_shuffle(3, 2, &config);
    for (rank, (x, y)) in a.iter().zip(&b).enumerate() {
        assert!(x.data_equals(y), "rank {rank}: replayed run diverged");
    }
}
