//! Property tests: Union / Intersect / Difference against a BTreeSet
//! oracle over rendered rows, plus algebraic invariants, on randomized
//! adversarial tables (nulls, NaNs, duplicates).

use rylon::io::generator::{random_table, SplitMix64};
use rylon::ops::{difference, intersect, union};
use rylon::table::{pretty::cell_to_string, Table};
use std::collections::BTreeSet;

fn row_set(t: &Table) -> BTreeSet<String> {
    (0..t.num_rows())
        .map(|r| {
            (0..t.num_columns())
                .map(|c| cell_to_string(t.column(c), r))
                .collect::<Vec<_>>()
                .join("\u{1}")
        })
        .collect()
}

#[test]
fn setops_match_btreeset_oracle() {
    let mut rng = SplitMix64::new(0x5E70);
    for case in 0..30 {
        let a = random_table(rng.next_below(80) as usize, rng.next_u64());
        let b = random_table(rng.next_below(80) as usize, rng.next_u64());
        let (sa, sb) = (row_set(&a), row_set(&b));

        let u = union(&a, &b).unwrap();
        assert_eq!(row_set(&u), sa.union(&sb).cloned().collect(), "case {case} union");
        // distinct output: no duplicate rows
        assert_eq!(u.num_rows(), row_set(&u).len(), "case {case} union distinct");

        let i = intersect(&a, &b).unwrap();
        assert_eq!(
            row_set(&i),
            sa.intersection(&sb).cloned().collect(),
            "case {case} intersect"
        );
        assert_eq!(i.num_rows(), row_set(&i).len());

        let d = difference(&a, &b).unwrap();
        assert_eq!(
            row_set(&d),
            sa.symmetric_difference(&sb).cloned().collect(),
            "case {case} difference"
        );
        assert_eq!(d.num_rows(), row_set(&d).len());
    }
}

#[test]
fn setop_algebraic_invariants() {
    let mut rng = SplitMix64::new(0xA16EB);
    for _ in 0..20 {
        let a = random_table(rng.next_below(60) as usize, rng.next_u64());
        let b = random_table(rng.next_below(60) as usize, rng.next_u64());
        let u = union(&a, &b).unwrap();
        let i = intersect(&a, &b).unwrap();
        let d = difference(&a, &b).unwrap();
        // |A ∪ B| = |A ∩ B| + |A Δ B|
        assert_eq!(u.num_rows(), i.num_rows() + d.num_rows());
        // commutativity
        assert_eq!(row_set(&u), row_set(&union(&b, &a).unwrap()));
        assert_eq!(row_set(&i), row_set(&intersect(&b, &a).unwrap()));
        assert_eq!(row_set(&d), row_set(&difference(&b, &a).unwrap()));
        // idempotence / annihilation
        assert_eq!(row_set(&union(&a, &a).unwrap()), row_set(&a));
        assert_eq!(difference(&a, &a).unwrap().num_rows(), 0);
        assert_eq!(row_set(&intersect(&a, &a).unwrap()), row_set(&a));
    }
}

#[test]
fn union_absorbs_intersection() {
    // (A ∪ B) ∩ A == distinct(A)
    let mut rng = SplitMix64::new(0xAB50B);
    for _ in 0..10 {
        let a = random_table(rng.next_below(50) as usize, rng.next_u64());
        let b = random_table(rng.next_below(50) as usize, rng.next_u64());
        let u = union(&a, &b).unwrap();
        let back = intersect(&u, &a).unwrap();
        assert_eq!(row_set(&back), row_set(&a));
    }
}
