//! Lifecycle property suite: the PR-8 acceptance matrix for
//! cooperative cancellation, deadlines, panic isolation, and teardown.
//!
//! * cancelling every rank mid-shuffle / mid-join / mid-sort surfaces
//!   a structured cancellation on **every rank** at threads 1/2/7 ×
//!   world 1/3 — never a hang;
//! * an expired deadline does the same with `DeadlineExceeded`;
//! * cancelling a **single** rank propagates to its peers over the
//!   wire ([`rylon::net::CANCEL_TAG`]) instead of timing them out;
//! * cancellation also aborts the reliable transport's ack/retry loops
//!   well inside the recv deadline;
//! * an injected panic in one morsel fails only its own query —
//!   sibling queries on their own tokens run to completion;
//! * fault-free runs are bit-identical at every thread count (the
//!   lifecycle checks are pure reads on the morsel path);
//! * a budgeted query cancelled mid-spill removes its scratch files.

use rylon::coordinator::run_workers;
use rylon::dataflow::Graph;
use rylon::error::Error;
use rylon::io::generator::{paper_table, random_table};
use rylon::lifecycle::{with_control, QueryControl};
use rylon::net::CommConfig;
use rylon::ops::join::JoinConfig;
use rylon::ops::parallel::{try_map_morsels, MORSEL_ROWS};
use rylon::table::Table;
use std::sync::mpsc;
use std::time::{Duration, Instant};

const THREADS: [usize; 3] = [1, 2, 7];

/// Generous no-hang bound: cancellation must land within one poll
/// interval (~10ms); anything near this bound means a rank waited for
/// a recv timeout instead of observing the token.
const HANG_BOUND: Duration = Duration::from_secs(15);

/// Cancel every rank mid-operator and require a structured
/// cancellation from every rank, at each (world, threads) cell.
fn cancel_matrix(op: &'static str) {
    for world in [1usize, 3] {
        for threads in THREADS {
            // Ranks export their tokens; the canceller collects all of
            // them, lets the op loops get airborne, then cancels.
            let (tx, rx) = mpsc::channel::<QueryControl>();
            let canceller = std::thread::spawn(move || {
                let ctls: Vec<_> = (0..world).map(|_| rx.recv().expect("ctl")).collect();
                std::thread::sleep(Duration::from_millis(10));
                for c in &ctls {
                    c.cancel();
                }
            });
            let start = Instant::now();
            let errs = run_workers(world, &CommConfig::default(), move |ctx| {
                ctx.set_parallelism(threads);
                tx.send(ctx.control().clone()).expect("export control");
                let l = random_table(200, 0x11F3 + ctx.rank() as u64);
                let r = random_table(200, 0x22F3 + ctx.rank() as u64);
                loop {
                    let res = match op {
                        "shuffle" => rylon::dist::shuffle(ctx, &l, 0).map(|_| ()),
                        "join" => rylon::dist::dist_join(ctx, &l, &r, &JoinConfig::inner(0, 0))
                            .map(|_| ()),
                        "sort" => rylon::dist::dist_sort(ctx, &l, 0).map(|_| ()),
                        other => unreachable!("unknown op {other}"),
                    };
                    if let Err(e) = res {
                        return e;
                    }
                }
            });
            canceller.join().expect("canceller thread");
            assert!(
                start.elapsed() < HANG_BOUND,
                "{op}: world={world} threads={threads} took {:?} — a rank hung",
                start.elapsed()
            );
            for (rank, e) in errs.iter().enumerate() {
                assert!(
                    e.is_cancellation(),
                    "{op}: world={world} threads={threads} rank={rank}: unstructured {e}"
                );
            }
        }
    }
}

#[test]
fn cancel_mid_shuffle_surfaces_on_every_rank() {
    cancel_matrix("shuffle");
}

#[test]
fn cancel_mid_join_surfaces_on_every_rank() {
    cancel_matrix("join");
}

#[test]
fn cancel_mid_sort_surfaces_on_every_rank() {
    cancel_matrix("sort");
}

#[test]
fn expired_deadline_surfaces_deadline_exceeded_on_every_rank() {
    for world in [1usize, 3] {
        for threads in THREADS {
            let start = Instant::now();
            let errs = run_workers(world, &CommConfig::default(), move |ctx| {
                ctx.set_parallelism(threads);
                ctx.control().set_timeout(Duration::ZERO);
                let t = random_table(200, 0x5EAD + ctx.rank() as u64);
                rylon::dist::dist_sort(ctx, &t, 0).expect_err("expired deadline must abort")
            });
            assert!(start.elapsed() < HANG_BOUND, "world={world} threads={threads}");
            for (rank, e) in errs.iter().enumerate() {
                assert!(
                    matches!(e, Error::DeadlineExceeded(_)),
                    "world={world} threads={threads} rank={rank}: {e}"
                );
                assert!(e.is_cancellation(), "rank {rank}: {e}");
            }
        }
    }
}

#[test]
fn mid_flight_deadline_aborts_like_a_cancel() {
    // Each rank arms a deadline that expires while the join loop is in
    // flight; every rank must surface DeadlineExceeded on its own.
    let start = Instant::now();
    let errs = run_workers(3, &CommConfig::default(), |ctx| {
        ctx.set_parallelism(2);
        ctx.control().set_timeout(Duration::from_millis(15));
        let l = random_table(150, 0x0D11 + ctx.rank() as u64);
        let r = random_table(150, 0x0D21 + ctx.rank() as u64);
        loop {
            if let Err(e) = rylon::dist::dist_join(ctx, &l, &r, &JoinConfig::inner(0, 0)) {
                return e;
            }
        }
    });
    assert!(start.elapsed() < HANG_BOUND, "took {:?}", start.elapsed());
    for (rank, e) in errs.iter().enumerate() {
        assert!(matches!(e, Error::DeadlineExceeded(_)), "rank {rank}: {e}");
    }
}

#[test]
fn single_rank_cancel_notifies_peers_over_the_wire() {
    // Only rank 0's token is cancelled; ranks 1 and 2 must learn via
    // the CANCEL_TAG notice — not by waiting out their recv timeout.
    let world = 3;
    let (tx, rx) = mpsc::channel::<(usize, QueryControl)>();
    let canceller = std::thread::spawn(move || {
        let mut ctls: Vec<(usize, QueryControl)> =
            (0..world).map(|_| rx.recv().expect("ctl")).collect();
        ctls.sort_by_key(|(rank, _)| *rank);
        std::thread::sleep(Duration::from_millis(10));
        ctls[0].1.cancel();
    });
    let start = Instant::now();
    let errs = run_workers(world, &CommConfig::default(), move |ctx| {
        tx.send((ctx.rank(), ctx.control().clone())).expect("export control");
        let t = random_table(200, 0x0CA0 + ctx.rank() as u64);
        loop {
            match rylon::dist::shuffle(ctx, &t, 0) {
                // The driver-loop idiom: a checkpoint between queries
                // both observes cancellation and (on the first failing
                // rank) sends the peer notice — `execute_plan` does the
                // same automatically on its error path.
                Ok(_) => {
                    if let Err(e) = ctx.checkpoint("between-queries") {
                        return e;
                    }
                }
                Err(e) => {
                    let _ = ctx.checkpoint("abort");
                    return e;
                }
            }
        }
    });
    canceller.join().expect("canceller thread");
    assert!(start.elapsed() < HANG_BOUND, "took {:?}", start.elapsed());
    for (rank, e) in errs.iter().enumerate() {
        assert!(e.is_cancellation(), "rank {rank}: unstructured {e}");
    }
    // At least one peer must have learned from the wire notice (its own
    // token was never cancelled locally before the notice arrived).
    assert!(
        errs.iter()
            .enumerate()
            .any(|(rank, e)| rank != 0 && e.to_string().contains("notice from peer")),
        "no peer saw the cancel notice: {errs:?}"
    );
}

#[test]
fn cancel_aborts_reliable_retry_loops_under_faults() {
    use rylon::net::{FaultPlan, RetryConfig};
    // Lossy link + reliable transport with a 20s recv deadline: the
    // cancel must end the run via the poll interval, not the deadline.
    let world = 3;
    let config = CommConfig::default()
        .with_faults(FaultPlan::new(0x1F3).with_drops(700))
        .with_reliability(true)
        .with_retry(RetryConfig::aggressive())
        .with_recv_timeout(Duration::from_secs(20));
    let (tx, rx) = mpsc::channel::<QueryControl>();
    let canceller = std::thread::spawn(move || {
        let ctls: Vec<_> = (0..world).map(|_| rx.recv().expect("ctl")).collect();
        std::thread::sleep(Duration::from_millis(10));
        for c in &ctls {
            c.cancel();
        }
    });
    let start = Instant::now();
    let errs = run_workers(world, &config, move |ctx| {
        tx.send(ctx.control().clone()).expect("export control");
        let t = random_table(150, 0x2E7 + ctx.rank() as u64);
        loop {
            if let Err(e) = rylon::dist::shuffle(ctx, &t, 0) {
                return e;
            }
        }
    });
    canceller.join().expect("canceller thread");
    assert!(
        start.elapsed() < Duration::from_secs(5),
        "cancel waited on the retry/ack loop: {:?}",
        start.elapsed()
    );
    for (rank, e) in errs.iter().enumerate() {
        assert!(e.is_cancellation(), "rank {rank}: unstructured {e}");
    }
}

#[test]
fn injected_morsel_panic_fails_only_that_query() {
    for threads in THREADS {
        // A sibling query on its own token runs concurrently and must
        // finish untouched by the other query's panic.
        let sibling = std::thread::spawn(move || {
            let ctl = QueryControl::new(0);
            with_control(&ctl, || {
                try_map_morsels(4 * MORSEL_ROWS, threads, |r| Ok::<usize, Error>(r.len()))
            })
        });
        let ctl = QueryControl::new(0);
        let err = with_control(&ctl, || {
            try_map_morsels(4 * MORSEL_ROWS, threads, |r| {
                if r.start == 2 * MORSEL_ROWS {
                    panic!("injected kernel panic");
                }
                Ok::<usize, Error>(r.len())
            })
        })
        .expect_err("panicking morsel must fail the query");
        assert!(matches!(err, Error::Internal(_)), "threads={threads}: {err:?}");
        assert!(err.to_string().contains("injected kernel panic"), "{err}");
        assert_eq!(ctl.worker_panics(), 1, "threads={threads}");
        assert!(ctl.is_cancelled(), "a panic stops the rest of the grid");
        assert_eq!(ctl.cancels(), 0, "note_panic is not a user cancel");
        let sib = sibling
            .join()
            .expect("sibling thread must exit cleanly")
            .expect("sibling query must be unaffected");
        assert_eq!(sib.iter().sum::<usize>(), 4 * MORSEL_ROWS, "threads={threads}");
    }
}

#[test]
fn fault_free_runs_are_bit_identical_across_thread_counts() {
    // The lifecycle checks on the morsel and superstep paths are pure
    // atomic reads: with no cancel in flight, outputs must stay
    // bit-identical at every thread count, world 1 and 3.
    for world in [1usize, 3] {
        let run = |threads: usize| -> Vec<Table> {
            run_workers(world, &CommConfig::default(), move |ctx| {
                ctx.set_parallelism(threads);
                let l = random_table(120, 0xB17 + ctx.rank() as u64);
                let r = random_table(120, 0xB27 + ctx.rank() as u64);
                let (j, _) =
                    rylon::dist::dist_join(ctx, &l, &r, &JoinConfig::inner(0, 0)).unwrap();
                rylon::dist::dist_sort(ctx, &j, 0).unwrap().0
            })
        };
        let oracle = run(1);
        for threads in [2usize, 7] {
            let got = run(threads);
            for (rank, (g, w)) in got.iter().zip(&oracle).enumerate() {
                assert!(g.data_equals(w), "world={world} threads={threads} rank={rank} diverged");
            }
        }
    }
}

#[test]
fn cancelled_budgeted_query_leaves_no_spill_files() {
    // Spill scratch dirs are named rylon_spill_<tag>_<pid>_<nanos>;
    // anything from this process left behind after a cancelled budgeted
    // query is a teardown leak.
    fn spill_dirs() -> std::collections::BTreeSet<String> {
        let marker = format!("_{}_", std::process::id());
        let mut v = std::collections::BTreeSet::new();
        if let Ok(rd) = std::fs::read_dir(std::env::temp_dir()) {
            for e in rd.flatten() {
                let name = e.file_name().to_string_lossy().into_owned();
                if name.starts_with("rylon_spill_") && name.contains(&marker) {
                    v.insert(name);
                }
            }
        }
        v
    }
    let before = spill_dirs();
    let mut g = Graph::new();
    let a = g.source("a");
    let b = g.source("b");
    let j = g.join(a, b, JoinConfig::inner(0, 0));
    let s = g.sort(j, 1);
    g.sink(s);
    let n = 2 * MORSEL_ROWS + 123;
    let srcs = [("a", paper_table(n, 0.8, 0xA1)), ("b", paper_table(n / 2, 0.8, 0xB2))];
    // Sweep the countdown so cancellation lands at different depths of
    // the budgeted (spilling) pipeline — node boundaries and morsel
    // boundaries alike. checks=1 cancels at the very first checkpoint,
    // so at least one run must error.
    let mut saw_cancel = false;
    for checks in [1u64, 5, 25, 125, 625] {
        let mut ctx = rylon::ctx::CylonContext::init_local();
        ctx.set_memory_budget(Some(1)); // everything is over budget
        ctx.control().cancel_after_checks(checks);
        match g.execute_with(&mut ctx, &srcs) {
            Ok(_) => {}
            Err(e) => {
                assert!(e.is_cancellation(), "checks={checks}: {e}");
                saw_cancel = true;
            }
        }
    }
    assert!(saw_cancel, "no countdown landed inside the query");
    assert_eq!(spill_dirs(), before, "cancelled budgeted queries leaked spill scratch dirs");
}
