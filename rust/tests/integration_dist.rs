//! Integration tests across coordinator + net + dist + ops: distributed
//! operators on randomly partitioned data must equal their local
//! counterparts on the concatenated data, for arbitrary world sizes;
//! failure injection must error, not hang.

use rylon::coordinator::{run_workers, try_run_workers};
use rylon::dist::testutil::{gather, row_multiset};
use rylon::io::generator::{random_table, SplitMix64};
use rylon::net::{CommConfig, FaultPlan, NetworkProfile, RetryConfig};
use rylon::ops::join::{nested_loop_join, JoinAlgorithm, JoinConfig, JoinType};
use rylon::table::Table;
use std::sync::Arc;

#[test]
fn dist_join_equals_local_all_types_random_worlds() {
    let mut rng = SplitMix64::new(0xD157);
    for case in 0..6 {
        let world = [1, 2, 3, 5][rng.next_below(4) as usize];
        let jt = [JoinType::Inner, JoinType::Left, JoinType::Right, JoinType::FullOuter]
            [case % 4];
        let alg = if case % 2 == 0 { JoinAlgorithm::Hash } else { JoinAlgorithm::Sort };
        let cfg = JoinConfig::new(jt, 0, 0).with_algorithm(alg);
        let lseed = rng.next_u64();
        let rseed = rng.next_u64();
        let lchunks: Arc<Vec<Table>> = Arc::new(
            (0..world).map(|w| random_table(40, lseed ^ w as u64)).collect(),
        );
        let rchunks: Arc<Vec<Table>> = Arc::new(
            (0..world).map(|w| random_table(40, rseed ^ w as u64)).collect(),
        );
        let lc = lchunks.clone();
        let rc = rchunks.clone();
        let outs = run_workers(world, &CommConfig::default(), move |ctx| {
            let rank = ctx.rank();
            rylon::dist::dist_join(ctx, &lc[rank], &rc[rank], &cfg)
                .unwrap()
                .0
        });
        let got = gather(outs);
        let want = nested_loop_join(
            &gather(lchunks.as_ref().clone()),
            &gather(rchunks.as_ref().clone()),
            &cfg,
        )
        .unwrap();
        assert_eq!(
            row_multiset(&got),
            row_multiset(&want),
            "case {case}: {jt:?}/{alg:?} world={world}"
        );
    }
}

#[test]
fn dist_setops_equal_local_on_random_data() {
    let mut rng = SplitMix64::new(0xD5E7);
    for world in [2, 4] {
        let aseed = rng.next_u64();
        let bseed = rng.next_u64();
        let ac: Arc<Vec<Table>> =
            Arc::new((0..world).map(|w| random_table(50, aseed ^ w as u64)).collect());
        let bc: Arc<Vec<Table>> =
            Arc::new((0..world).map(|w| random_table(50, bseed ^ w as u64)).collect());
        let (a2, b2) = (ac.clone(), bc.clone());
        let outs = run_workers(world, &CommConfig::default(), move |ctx| {
            let rank = ctx.rank();
            let (u, _) = rylon::dist::dist_union(ctx, &a2[rank], &b2[rank]).unwrap();
            let (i, _) = rylon::dist::dist_intersect(ctx, &a2[rank], &b2[rank]).unwrap();
            let (d, _) = rylon::dist::dist_difference(ctx, &a2[rank], &b2[rank]).unwrap();
            (u, i, d)
        });
        let ga = gather(ac.as_ref().clone());
        let gb = gather(bc.as_ref().clone());
        let gu = gather(outs.iter().map(|o| o.0.clone()).collect());
        let gi = gather(outs.iter().map(|o| o.1.clone()).collect());
        let gd = gather(outs.into_iter().map(|o| o.2).collect());
        assert_eq!(
            row_multiset(&gu),
            row_multiset(&rylon::ops::union(&ga, &gb).unwrap()),
            "union world={world}"
        );
        assert_eq!(
            row_multiset(&gi),
            row_multiset(&rylon::ops::intersect(&ga, &gb).unwrap()),
            "intersect world={world}"
        );
        assert_eq!(
            row_multiset(&gd),
            row_multiset(&rylon::ops::difference(&ga, &gb).unwrap()),
            "difference world={world}"
        );
    }
}

#[test]
fn network_profile_does_not_change_results() {
    // §II-D: transports swap under the operators without touching them.
    for profile in [NetworkProfile::Loopback, NetworkProfile::Infiniband40G] {
        let cfg = CommConfig::default().with_profile(profile);
        let outs = run_workers(3, &cfg, move |ctx| {
            let l = random_table(60, 42 + ctx.rank() as u64);
            let r = random_table(60, 77 + ctx.rank() as u64);
            rylon::dist::dist_join(ctx, &l, &r, &JoinConfig::inner(0, 0))
                .unwrap()
                .0
                .num_rows()
        });
        let total: usize = outs.iter().sum();
        // Same seeds per rank: the row count must be identical across
        // profiles (compare to a fresh loopback run).
        let base = run_workers(3, &CommConfig::default(), move |ctx| {
            let l = random_table(60, 42 + ctx.rank() as u64);
            let r = random_table(60, 77 + ctx.rank() as u64);
            rylon::dist::dist_join(ctx, &l, &r, &JoinConfig::inner(0, 0))
                .unwrap()
                .0
                .num_rows()
        });
        assert_eq!(total, base.iter().sum::<usize>(), "{profile:?}");
    }
}

#[test]
fn dropped_message_fails_cleanly_not_hangs() {
    // Drop every data message without the reliable layer: the shuffle
    // must surface a comm error (timeout) on some worker, not deadlock.
    let config = CommConfig::default()
        .with_faults(FaultPlan::drop_all(0xD1))
        .with_recv_timeout(std::time::Duration::from_millis(200));
    let result: rylon::error::Result<Vec<usize>> =
        try_run_workers(2, &config, None, move |ctx| {
            let t = random_table(30, 5 + ctx.rank() as u64);
            let (out, _) = rylon::dist::shuffle(ctx, &t, 0)?;
            Ok(out.num_rows())
        });
    // Workers race: at least the whole job must fail.
    assert!(result.is_err(), "dropped message should fail the job");
}

#[test]
fn corrupted_message_is_detected() {
    let config = CommConfig::default()
        .with_faults(FaultPlan::corrupt_all(0xC0))
        .with_recv_timeout(std::time::Duration::from_millis(500));
    let result: rylon::error::Result<Vec<usize>> =
        try_run_workers(2, &config, None, move |ctx| {
            let t = random_table(30, 9 + ctx.rank() as u64);
            let (out, _) = rylon::dist::shuffle(ctx, &t, 0)?;
            Ok(out.num_rows())
        });
    // The corrupted first byte breaks the wire magic => comm error.
    assert!(result.is_err(), "corrupt message should fail deserialization");
}

#[test]
fn reliability_masks_the_same_faults() {
    // The exact schedules that fail the two tests above are fully
    // recovered by the reliable (checksum + ack/retransmit) layer, with
    // output bit-identical to a fault-free run.
    let want = run_workers(3, &CommConfig::default(), move |ctx| {
        let t = random_table(30, 5 + ctx.rank() as u64);
        rylon::dist::shuffle(ctx, &t, 0).unwrap().0
    });
    for (label, plan) in [
        ("drops", FaultPlan::drop_all(0xD1).with_max_consecutive_faults(1)),
        ("corruption", FaultPlan::corrupt_all(0xC0).with_max_consecutive_faults(1)),
    ] {
        let config = CommConfig::default()
            .with_faults(plan)
            .with_reliability(true)
            .with_retry(RetryConfig::aggressive())
            .with_recv_timeout(std::time::Duration::from_secs(10));
        let got = run_workers(3, &config, move |ctx| {
            let t = random_table(30, 5 + ctx.rank() as u64);
            let (out, stats) = rylon::dist::shuffle(ctx, &t, 0).unwrap();
            (out, stats)
        });
        for (rank, ((g, stats), w)) in got.iter().zip(&want).enumerate() {
            assert!(g.data_equals(w), "{label}: rank {rank} diverged under faults");
            if label == "drops" {
                // every original transmission was dropped => each rank
                // retransmitted at least one frame before its acks came
                assert!(stats.frames_retried > 0, "{label}: rank {rank} {stats:?}");
            } else {
                // every original frame was corrupted => the receiver
                // saw and masked at least one bad checksum
                assert!(stats.frames_corrupt > 0, "{label}: rank {rank} {stats:?}");
            }
        }
    }
}

#[test]
fn worker_panic_reported_as_error() {
    let r: rylon::error::Result<Vec<()>> =
        try_run_workers(2, &CommConfig::default(), None, |ctx| {
            if ctx.rank() == 1 {
                panic!("deliberate");
            }
            Ok(())
        });
    assert!(r.is_err());
}

#[test]
fn no_leaked_rylon_threads_after_context_drop() {
    // Every thread this crate spawns carries a "rylon-" name prefix
    // (workers, tcp readers). After run_workers returns — healthy or
    // cancelled — the per-worker CylonContext drops must have joined
    // everything, so the name-filtered count returns to its baseline.
    fn rylon_threads() -> usize {
        let Ok(tasks) = std::fs::read_dir("/proc/self/task") else { return 0 };
        tasks
            .flatten()
            .filter(|t| {
                std::fs::read_to_string(t.path().join("comm"))
                    .unwrap_or_default()
                    .starts_with("rylon-")
            })
            .count()
    }
    let before = rylon_threads();
    let _ = run_workers(3, &CommConfig::default(), |ctx| {
        let t = random_table(40, 0x7EAD + ctx.rank() as u64);
        rylon::dist::shuffle(ctx, &t, 0).unwrap().0.num_rows()
    });
    // A cancelled run tears down through the error path.
    let cancelled: rylon::error::Result<Vec<()>> =
        try_run_workers(3, &CommConfig::default(), None, |ctx| {
            ctx.control().cancel();
            let t = random_table(40, 0x7EAE + ctx.rank() as u64);
            rylon::dist::shuffle(ctx, &t, 0).map(|_| ())
        });
    assert!(cancelled.is_err(), "pre-cancelled run must fail");
    // Other tests in this binary run concurrently and spawn their own
    // rylon-worker threads, so poll for the count to settle instead of
    // asserting a single snapshot.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    loop {
        let now = rylon_threads();
        if now <= before {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "leaked rylon-* threads: {now} alive, baseline {before}"
        );
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
}
