//! Cross-layer bit-exactness: the native Rust hash must equal the
//! JAX/Pallas reference (`python/compile/kernels/ref.py`) on the pinned
//! golden fixture `tests/fixtures/golden_hash.tsv`, which
//! `python/tests/test_golden_hash.py` asserts against the Python side
//! of the contract. Regenerate with `python -m compile.kernels.ref`.
//!
//! If this test fails, the routing contract between the AOT artifact
//! and the native fallback is broken — distributed joins would route
//! the same key to different workers depending on which path ran.

use rylon::ops::hash::hash_i64;

/// The committed fixture, shared verbatim with the Python tests.
const FIXTURE: &str = include_str!("fixtures/golden_hash.tsv");

/// Parse `key<TAB>hex` lines, skipping comments and blanks.
fn golden_pairs() -> Vec<(i64, u32)> {
    FIXTURE
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(|l| {
            let (k, h) = l.split_once('\t').expect("fixture line has a tab");
            (
                k.parse::<i64>().expect("fixture key parses as i64"),
                u32::from_str_radix(h, 16).expect("fixture hash parses as hex u32"),
            )
        })
        .collect()
}

#[test]
fn fixture_is_well_formed() {
    let pairs = golden_pairs();
    assert_eq!(pairs.len(), 11, "fixture should pin 11 vectors");
    // The interesting boundary keys must be present.
    let keys: Vec<i64> = pairs.iter().map(|(k, _)| *k).collect();
    for k in [0, 1, -1, i64::MAX, i64::MIN, i32::MAX as i64, i32::MAX as i64 + 1] {
        assert!(keys.contains(&k), "fixture missing boundary key {k}");
    }
}

#[test]
fn native_hash_matches_golden_fixture() {
    for (key, want) in golden_pairs() {
        assert_eq!(
            hash_i64(key),
            want,
            "hash_i64({key}) diverged from the committed golden fixture \
             (kernels/ref.py is the oracle)"
        );
    }
}

#[test]
fn fmix32_one_is_murmur_constant() {
    // fmix32(1) is a well-known murmur3 constant; pin it independently.
    assert_eq!(rylon::ops::hash::fmix32(1), 0x514e28b7);
}

#[test]
fn partition_path_routes_golden_keys_by_committed_hashes() {
    // The property the contract exists for: the shuffle's actual
    // partition-id computation (including the null-free int64 fast
    // path) must route the golden keys exactly as the committed hash
    // values dictate, for any world size.
    use rylon::ops::partition::partition_ids_by_key;
    use rylon::table::{Array, Table};

    let pairs = golden_pairs();
    let keys: Vec<i64> = pairs.iter().map(|(k, _)| *k).collect();
    let t = Table::from_arrays(vec![("k", Array::from_i64(keys))]).unwrap();
    for world in [1usize, 2, 5, 16, 160] {
        let ids = partition_ids_by_key(&t, 0, world).unwrap();
        for ((key, hash), id) in pairs.iter().zip(&ids) {
            assert_eq!(*id, hash % world as u32, "key {key} world {world}");
        }
    }
}
