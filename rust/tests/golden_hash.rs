//! Cross-layer bit-exactness: the native Rust hash must equal the
//! JAX/Pallas reference (`python/compile/kernels/ref.py`) on pinned
//! golden vectors. Regenerate with `python -m compile.kernels.ref`.
//!
//! If this test fails, the routing contract between the AOT artifact
//! and the native fallback is broken — distributed joins would route
//! the same key to different workers depending on which path ran.

use rylon::ops::hash::hash_i64;

/// (key, fmix32-based hash) pairs emitted by ref.py.
const GOLDEN: &[(i64, u32)] = &[
    (0, 0x00000000),
    (1, 0x514e28b7),
    (-1, 0xce2d4699),
    (42, 0x087fcd5c),
    (-42, 0x6365c8fd),
    (2147483647, 0xf9cc0ea8),
    (2147483648, 0x6d3c65a0),
    (9223372036854775807, 0xc17a5544),
    (-9223372036854775808, 0x2390fe25),
    (81985529216486895, 0x5f5ab57b),
    (-81985529216486895, 0xa83fb934),
];

#[test]
fn native_hash_matches_jax_reference() {
    for &(key, want) in GOLDEN {
        assert_eq!(
            hash_i64(key),
            want,
            "hash_i64({key}) diverged from kernels/ref.py"
        );
    }
}

#[test]
fn fmix32_one_is_murmur_constant() {
    // fmix32(1) is a well-known murmur3 constant; pin it independently.
    assert_eq!(rylon::ops::hash::fmix32(1), 0x514e28b7);
}
