//! Property tests: join correctness against the nested-loop oracle over
//! randomized inputs (sizes, null keys, duplicate keys, all four join
//! semantics, both algorithms).
//!
//! proptest is not vendored in this offline image; the same discipline
//! is hand-rolled: a deterministic seed sweep over a generator of
//! adversarial tables, with multiset comparison of outputs.

use rylon::io::generator::{random_table, SplitMix64};
use rylon::ops::join::{join, nested_loop_join, JoinAlgorithm, JoinConfig, JoinType};
use rylon::table::{pretty::cell_to_string, Table};
use std::collections::BTreeMap;

/// Order-insensitive multiset of rendered rows.
fn row_multiset(t: &Table) -> BTreeMap<String, usize> {
    let mut m = BTreeMap::new();
    for r in 0..t.num_rows() {
        let key = (0..t.num_columns())
            .map(|c| cell_to_string(t.column(c), r))
            .collect::<Vec<_>>()
            .join("\u{1}");
        *m.entry(key).or_insert(0) += 1;
    }
    m
}

const TYPES: [JoinType; 4] = [
    JoinType::Inner,
    JoinType::Left,
    JoinType::Right,
    JoinType::FullOuter,
];

#[test]
fn join_matches_nested_loop_oracle_randomized() {
    let mut rng = SplitMix64::new(0xA11CE);
    for case in 0..40 {
        let nl = rng.next_below(60) as usize;
        let nr = rng.next_below(60) as usize;
        let l = random_table(nl, rng.next_u64());
        let r = random_table(nr, rng.next_u64());
        let jt = TYPES[(case % 4) as usize];
        for alg in [JoinAlgorithm::Hash, JoinAlgorithm::Sort] {
            let cfg = JoinConfig::new(jt, 0, 0).with_algorithm(alg);
            let got = join(&l, &r, &cfg).unwrap();
            let want = nested_loop_join(&l, &r, &cfg).unwrap();
            assert_eq!(
                row_multiset(&got),
                row_multiset(&want),
                "case {case}: {jt:?}/{alg:?} nl={nl} nr={nr}"
            );
        }
    }
}

#[test]
fn hash_and_sort_join_agree_on_float_keys() {
    // Float keys exercise NaN/total-order paths (column 1 of
    // random_table is f64 with nulls and NaNs).
    let mut rng = SplitMix64::new(0xF10A7);
    for case in 0..20 {
        let l = random_table(rng.next_below(50) as usize, rng.next_u64());
        let r = random_table(rng.next_below(50) as usize, rng.next_u64());
        let jt = TYPES[(case % 4) as usize];
        let h = join(&l, &r, &JoinConfig::new(jt, 1, 1).with_algorithm(JoinAlgorithm::Hash))
            .unwrap();
        let s = join(&l, &r, &JoinConfig::new(jt, 1, 1).with_algorithm(JoinAlgorithm::Sort))
            .unwrap();
        assert_eq!(row_multiset(&h), row_multiset(&s), "case {case}: {jt:?}");
    }
}

#[test]
fn join_on_string_keys_agrees() {
    let mut rng = SplitMix64::new(0x57215);
    for case in 0..20 {
        let l = random_table(rng.next_below(40) as usize, rng.next_u64());
        let r = random_table(rng.next_below(40) as usize, rng.next_u64());
        let cfg_h = JoinConfig::inner(2, 2).with_algorithm(JoinAlgorithm::Hash);
        let cfg_s = JoinConfig::inner(2, 2).with_algorithm(JoinAlgorithm::Sort);
        let h = join(&l, &r, &cfg_h).unwrap();
        let s = join(&l, &r, &cfg_s).unwrap();
        let o = nested_loop_join(&l, &r, &cfg_h).unwrap();
        assert_eq!(row_multiset(&h), row_multiset(&o), "case {case} hash");
        assert_eq!(row_multiset(&s), row_multiset(&o), "case {case} sort");
    }
}

#[test]
fn outer_join_row_count_invariants() {
    // |full| = |inner| + |left-only| + |right-only|;
    // |left| = |inner| + |left-only|, and symmetrically for right.
    let mut rng = SplitMix64::new(0x0C7E7);
    for _ in 0..20 {
        let l = random_table(rng.next_below(50) as usize, rng.next_u64());
        let r = random_table(rng.next_below(50) as usize, rng.next_u64());
        let n = |jt: JoinType| {
            join(&l, &r, &JoinConfig::new(jt, 0, 0)).unwrap().num_rows() as i64
        };
        let (inner, left, right, full) = (
            n(JoinType::Inner),
            n(JoinType::Left),
            n(JoinType::Right),
            n(JoinType::FullOuter),
        );
        assert_eq!(full, left + right - inner, "inclusion-exclusion");
        assert!(left >= inner && right >= inner);
    }
}

#[test]
fn join_output_schema_and_width() {
    let mut rng = SplitMix64::new(0x5CE14);
    for _ in 0..10 {
        let l = random_table(rng.next_below(20) as usize + 1, rng.next_u64());
        let r = random_table(rng.next_below(20) as usize + 1, rng.next_u64());
        let out = join(&l, &r, &JoinConfig::inner(0, 0)).unwrap();
        assert_eq!(out.num_columns(), l.num_columns() + r.num_columns());
        // right-side duplicate names must be suffixed
        assert_eq!(out.schema().field(l.num_columns()).name, "k_r");
    }
}
