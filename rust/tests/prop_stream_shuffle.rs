//! Property tests for the chunked streaming shuffle (PR 10): the
//! streamed path must be **bit-identical** to the monolithic
//! `shuffle_tables` — same assembled bytes, same decoded table — at
//! threads 1/2/7 and world 1/3, with chunk sizes small enough to force
//! many frames per part, and under every retryable fault schedule
//! (drops force retransmission, so duplicate frames cross the reliable
//! layer's dedup and the receiver's idempotent byte-range placement).
//!
//! Chunk boundaries are a pure function of the wire image's extents
//! index, so none of this may depend on thread count, arrival order,
//! or fault timing.

use rylon::coordinator::run_workers;
use rylon::net::serialize::serialize_table_par;
use rylon::net::{CommConfig, FaultPlan, RetryConfig};
use rylon::table::take::take_table;
use rylon::table::{Array, Table, Utf8Array};

const THREADS: [usize; 3] = [1, 2, 7];

/// Reliability stack over a seeded fault plan, retrying aggressively —
/// the same configuration the fault-matrix suite pins.
fn reliable(plan: FaultPlan) -> CommConfig {
    CommConfig::default()
        .with_faults(plan)
        .with_reliability(true)
        .with_retry(RetryConfig::aggressive())
}

/// The retryable schedules of the fault matrix, under the streamed
/// path this time. Chunked frames mean each schedule now hits many
/// more wire messages per superstep than the monolithic path did.
fn retryable_schedules() -> Vec<(&'static str, FaultPlan)> {
    vec![
        ("drops", FaultPlan::new(0x57A1).with_drops(700)),
        ("corruption", FaultPlan::new(0x57A2).with_corruption(500)),
        ("delays", FaultPlan::new(0x57A3).with_delays(600)),
        (
            "mixed",
            FaultPlan::new(0x57A4).with_drops(300).with_corruption(200).with_delays(200),
        ),
    ]
}

/// Deterministic destination split: row `r` goes to part `r % world`.
/// Input-derived, so every rank's parts are a pure function of its
/// table, whatever the thread budget.
fn split_by_row_mod(t: &Table, world: usize) -> Vec<Table> {
    (0..world)
        .map(|d| {
            let rows: Vec<usize> = (0..t.num_rows()).filter(|r| r % world == d).collect();
            take_table(t, &rows)
        })
        .collect()
}

/// A null-and-utf8-heavy per-rank table: empty strings, multibyte,
/// long values, ~40% nulls — the shapes whose wire blocks have ragged,
/// unaligned extents.
fn adversarial_table(rows: usize, seed: u64) -> Table {
    let strings: Vec<Option<String>> = (0..rows)
        .map(|r| match (r as u64 + seed) % 5 {
            0 | 1 => None,
            2 => Some(String::new()),
            3 => Some("wörld-ü-∞".to_string()),
            _ => Some(format!("s{seed}-{r}")),
        })
        .collect();
    Table::from_arrays(vec![
        (
            "i",
            Array::from_i64_opts(
                (0..rows).map(|r| (r % 3 != 0).then_some(r as i64 - 7)).collect(),
            ),
        ),
        ("s", Array::Utf8(Utf8Array::from_options(&strings))),
        ("f", Array::from_f64((0..rows).map(|r| r as f64 * 0.5).collect())),
    ])
    .unwrap()
}

/// Streamed output per rank for a (world, threads, chunk, config) cell,
/// asserting in-worker that it is byte-identical to the monolithic
/// shuffle of the same parts.
fn run_cell(
    world: usize,
    threads: usize,
    chunk: usize,
    config: &CommConfig,
    check_against_monolithic: bool,
) -> Vec<Table> {
    run_workers(world, config, move |ctx| {
        ctx.set_parallelism(threads);
        let t = adversarial_table(160 + 40 * ctx.rank(), 0x5EED + ctx.rank() as u64);
        let parts = split_by_row_mod(&t, ctx.world());
        let comm = ctx.communicator();
        let mono = if check_against_monolithic {
            Some(comm.shuffle_tables(parts.clone()).unwrap())
        } else {
            None
        };
        let streamed = comm.shuffle_tables_streamed_chunked(parts, chunk).unwrap();
        if let Some(mono) = mono {
            // Byte identity, not just value equality: the assembled
            // receive buffers must reproduce the monolithic wire image.
            assert_eq!(
                serialize_table_par(&streamed, 1),
                serialize_table_par(&mono, 1),
                "rank {}: streamed wire image diverged",
                ctx.rank()
            );
        }
        streamed
    })
}

#[test]
fn streamed_equals_monolithic_at_every_thread_count_and_world() {
    // 96-byte chunks force dozens of frames per part; usize::MAX forces
    // exactly one frame per part (the degenerate chunk-larger-than-part
    // shape). Both must reproduce the monolithic bytes.
    for world in [1usize, 3] {
        for chunk in [96usize, 1 << 30] {
            let base = run_cell(world, 1, chunk, &CommConfig::default(), true);
            for threads in [2usize, 7] {
                let got = run_cell(world, threads, chunk, &CommConfig::default(), true);
                for (rank, (g, b)) in got.iter().zip(&base).enumerate() {
                    assert!(
                        g.data_equals(b),
                        "world={world} chunk={chunk} threads={threads} rank={rank}"
                    );
                    assert_eq!(g.schema(), b.schema(), "world={world} rank={rank}");
                }
            }
        }
    }
}

#[test]
fn streamed_bit_identical_under_retryable_fault_schedules() {
    // Fault-free monolithic output is the oracle; the streamed path
    // under drops/corruption/delays must match it bit-for-bit. Drops
    // and delays make the reliable layer retransmit chunk frames, so
    // duplicates reach dedup and (where dedup re-acks) the receiver's
    // idempotent placement — none of it may show in the output.
    for world in [1usize, 3] {
        let oracle = run_cell(world, 1, 128, &CommConfig::default(), true);
        for (label, plan) in retryable_schedules() {
            for threads in THREADS {
                let got = run_cell(world, threads, 128, &reliable(plan.clone()), false);
                for (rank, (g, w)) in got.iter().zip(&oracle).enumerate() {
                    assert!(
                        g.data_equals(w),
                        "{label}: world={world} threads={threads} rank={rank} diverged"
                    );
                }
            }
        }
    }
}

#[test]
fn streamed_skewed_routing_with_empty_parts() {
    // Rank r routes every row to rank (r + 1) % world: each rank
    // receives exactly one non-empty remote part and world-2 empty
    // ones (header-only single-chunk frames), and its own loopback
    // part is empty too. Streamed must equal monolithic through the
    // ragged final chunks and the empties alike.
    let world = 3;
    let run = |threads: usize| -> Vec<Table> {
        run_workers(world, &CommConfig::default(), move |ctx| {
            ctx.set_parallelism(threads);
            let t = adversarial_table(90, 0xCAFE + ctx.rank() as u64);
            let dst = (ctx.rank() + 1) % ctx.world();
            let parts: Vec<Table> = (0..ctx.world())
                .map(|d| {
                    let rows: Vec<usize> =
                        if d == dst { (0..t.num_rows()).collect() } else { Vec::new() };
                    take_table(&t, &rows)
                })
                .collect();
            let comm = ctx.communicator();
            let mono = comm.shuffle_tables(parts.clone()).unwrap();
            let streamed = comm.shuffle_tables_streamed_chunked(parts, 64).unwrap();
            assert_eq!(
                serialize_table_par(&streamed, 1),
                serialize_table_par(&mono, 1),
                "rank {}",
                ctx.rank()
            );
            assert_eq!(streamed.num_rows(), 90, "rank {} receives one part", ctx.rank());
            streamed
        })
    };
    let base = run(1);
    for threads in [2usize, 7] {
        let got = run(threads);
        for (rank, (g, b)) in got.iter().zip(&base).enumerate() {
            assert!(g.data_equals(b), "threads={threads} rank={rank}");
        }
    }
}
