//! Property tests for the tracing subsystem's observation-only
//! contract: with tracing on, every operator's output is
//! **bit-identical** to the untraced run at parallelism 1/2/7 and
//! world 1/3 — while the recorded span tree stays well-formed (every
//! parent exists, no span ends before it starts, exactly one plan
//! span per executed node per rank) and the Chrome-trace export
//! round-trips the span count.

use rylon::coordinator::run_workers;
use rylon::ctx::CylonContext;
use rylon::dataflow::Graph;
use rylon::io::generator::paper_table;
use rylon::net::CommConfig;
use rylon::ops::aggregate::{AggFn, AggSpec};
use rylon::ops::expr::Expr;
use rylon::ops::join::JoinConfig;
use rylon::table::Table;
use rylon::trace::{Span, SpanKind, TraceSink};
use std::collections::HashSet;

/// join → filter → group-by and a sorted branch: one graph covering
/// the shuffle, join, group-by and sort paths at once.
fn pipeline() -> Graph {
    let mut g = Graph::new();
    let a = g.source("a");
    let b = g.source("b");
    let j = g.join(a, b, JoinConfig::inner(0, 0));
    let f = g.filter(j, Expr::col(1).lt(Expr::lit_f64(0.6)));
    let gb = g.group_by(f, 0, vec![AggSpec::new(AggFn::Sum, 1)]);
    let s = g.sort(j, 1);
    g.sink(gb);
    g.sink(s);
    g
}

fn sources(rows: usize, seed: u64) -> [(&'static str, Table); 2] {
    [
        ("a", paper_table(rows, 0.6, seed)),
        ("b", paper_table(rows, 0.6, seed ^ 0xACE)),
    ]
}

#[test]
fn tracing_is_bit_identical_world1() {
    let g = pipeline();
    let srcs = sources(2_000, 0x7A1);
    for threads in [1usize, 2, 7] {
        let mut plain = CylonContext::init_local().with_parallelism(threads);
        let want = g.execute_with(&mut plain, &srcs).unwrap();
        let mut traced = CylonContext::init_local().with_parallelism(threads);
        traced.set_tracing(true);
        let got = g.execute_with(&mut traced, &srcs).unwrap();
        assert_eq!(want.len(), got.len());
        for (k, (w, t)) in want.iter().zip(&got).enumerate() {
            assert!(t.data_equals(w), "threads {threads} sink {k}");
        }
        assert!(traced.trace().span_count() > 0, "threads {threads}: spans recorded");
        assert_eq!(plain.trace().span_count(), 0, "disabled sink records nothing");
    }
}

#[test]
fn tracing_is_bit_identical_world3() {
    let world = 3;
    let run = |tracing: bool| -> Vec<Vec<Table>> {
        run_workers(world, &CommConfig::default(), move |ctx| {
            ctx.set_tracing(tracing);
            let srcs = sources(700, 0x7A3 + ctx.rank() as u64);
            let g = pipeline();
            for threads in [1usize, 2, 7] {
                ctx.set_parallelism(threads);
                let r = g.execute_with(ctx, &srcs).unwrap();
                if threads == 7 {
                    return r;
                }
                // intermediate thread counts must agree too
                let again = g.execute_with(ctx, &srcs).unwrap();
                for (x, y) in r.iter().zip(&again) {
                    assert!(x.data_equals(y), "rerun variance at threads {threads}");
                }
            }
            unreachable!()
        })
    };
    let plain = run(false);
    let traced = run(true);
    for (rank, (w, t)) in plain.iter().zip(&traced).enumerate() {
        assert_eq!(w.len(), t.len());
        for (k, (wt, tt)) in w.iter().zip(t).enumerate() {
            assert!(tt.data_equals(wt), "rank {rank} sink {k}");
        }
    }
}

#[test]
fn traced_direct_shuffle_is_bit_identical() {
    // Direct dist calls (no plan executor): install the sink by hand,
    // as the coordinator does for contexts that start with tracing on.
    let world = 3;
    for threads in [1usize, 2, 7] {
        let run = |tracing: bool| -> Vec<Table> {
            run_workers(world, &CommConfig::default(), move |ctx| {
                ctx.set_parallelism(threads);
                let t = paper_table(500, 0.7, 0x5F + ctx.rank() as u64);
                if tracing {
                    let sink = TraceSink::new(1, ctx.rank());
                    let out = rylon::trace::with_sink(&sink, || {
                        rylon::dist::shuffle(ctx, &t, 0).unwrap().0
                    });
                    assert!(sink.span_count() > 0, "shuffle emitted spans");
                    out
                } else {
                    rylon::dist::shuffle(ctx, &t, 0).unwrap().0
                }
            })
        };
        let plain = run(false);
        let traced = run(true);
        for (rank, (w, t)) in plain.iter().zip(&traced).enumerate() {
            assert!(t.data_equals(w), "rank {rank} threads {threads}");
        }
    }
}

/// Well-formedness of one rank's span set: ids unique, parents exist
/// (or 0), time never runs backwards within a span.
fn assert_rank_spans_well_formed(rank: usize, spans: &[&Span]) {
    let ids: HashSet<u64> = spans.iter().map(|s| s.span_id).collect();
    assert_eq!(ids.len(), spans.len(), "rank {rank}: span ids unique");
    for s in spans {
        assert!(s.t_end_ns >= s.t_start_ns, "rank {rank}: span {} ends before start", s.label);
        assert!(
            s.parent_id == 0 || ids.contains(&s.parent_id),
            "rank {rank}: span {} has unknown parent {}",
            s.label,
            s.parent_id
        );
    }
}

#[test]
fn gathered_span_tree_is_well_formed() {
    let world = 3;
    let outs = run_workers(world, &CommConfig::default(), move |ctx| {
        let srcs = sources(600, 0x90 + ctx.rank() as u64);
        let report = pipeline().explain_analyze(ctx, &srcs).unwrap();
        (ctx.rank() == 0).then(|| (report, ctx.trace().spans()))
    });
    let (report, spans) = outs.into_iter().flatten().next().expect("rank 0 trace");
    assert!(report.contains("== explain analyze"), "{report}");

    let ranks: HashSet<usize> = spans.iter().map(|s| s.rank).collect();
    assert_eq!(ranks.len(), world, "all ranks gathered: {ranks:?}");
    let mut plan_count: Option<usize> = None;
    for r in 0..world {
        let rs: Vec<&Span> = spans.iter().filter(|s| s.rank == r).collect();
        assert_rank_spans_well_formed(r, &rs);
        // exactly one Query root per rank
        assert_eq!(
            rs.iter().filter(|s| s.kind == SpanKind::Query).count(),
            1,
            "rank {r}: one query root"
        );
        // exactly one Plan span per executed node per rank: labels
        // `#<id> <op>` are unique within the rank, and every rank
        // executed the same optimized plan.
        let labels: Vec<&str> = rs
            .iter()
            .filter(|s| s.kind == SpanKind::Plan)
            .map(|s| s.label.as_str())
            .collect();
        let distinct: HashSet<&&str> = labels.iter().collect();
        assert_eq!(distinct.len(), labels.len(), "rank {r}: duplicate plan spans {labels:?}");
        assert!(!labels.is_empty(), "rank {r}: plan spans recorded");
        match plan_count {
            None => plan_count = Some(labels.len()),
            Some(n) => assert_eq!(n, labels.len(), "rank {r}: same executed node count"),
        }
        // every layer the pipeline exercises shows up
        for kind in [SpanKind::Grid, SpanKind::Superstep, SpanKind::Wire] {
            assert!(
                rs.iter().any(|s| s.kind == kind),
                "rank {r}: no {} span",
                kind.as_str()
            );
        }
    }
}

/// Minimal structural JSON scan: balanced braces/brackets outside
/// string literals (the CI smoke does a full `json.loads`; this keeps
/// the guarantee toolchain-independent).
fn assert_balanced_json(s: &str) {
    let (mut depth, mut in_str, mut esc) = (0i64, false, false);
    for c in s.chars() {
        if in_str {
            if esc {
                esc = false;
            } else if c == '\\' {
                esc = true;
            } else if c == '"' {
                in_str = false;
            }
            continue;
        }
        match c {
            '"' => in_str = true,
            '{' | '[' => depth += 1,
            '}' | ']' => {
                depth -= 1;
                assert!(depth >= 0, "unbalanced close");
            }
            _ => {}
        }
    }
    assert!(!in_str, "unterminated string");
    assert_eq!(depth, 0, "unbalanced braces");
}

#[test]
fn chrome_trace_round_trips_span_count() {
    let g = pipeline();
    let srcs = sources(1_200, 0xC0);
    let mut ctx = CylonContext::init_local().with_parallelism(2);
    ctx.set_tracing(true);
    let _ = g.execute_with(&mut ctx, &srcs).unwrap();
    let sink = ctx.trace();
    let n = sink.span_count();
    assert!(n > 0);
    let json = sink.to_chrome_trace();
    assert_balanced_json(&json);
    // one complete event per span, exactly — identified by its span_id
    // arg (synthesized per-worker lanes carry no span_id)
    assert_eq!(json.matches("\"span_id\":").count(), n, "span count round-trips");
    assert!(json.matches("\"ph\":\"X\"").count() >= n);
    for key in ["\"ts\":", "\"dur\":", "\"pid\":", "\"tid\":", "\"name\":"] {
        assert!(json.contains(key), "missing {key}");
    }
}
