//! Integration tests for the AOT (JAX/Pallas → PJRT) path: the kernel
//! must route identically to the native hash in real shuffles, and the
//! sim results must be invariant to which path computed the ids.
//!
//! These tests skip (with a note) when `artifacts/` has not been built;
//! `make test` builds artifacts first, so CI exercises them.

use rylon::coordinator::try_run_workers;
use rylon::io::generator::paper_table;
use rylon::net::{CommConfig, NetworkProfile};
use rylon::ops::join::JoinConfig;
use rylon::runtime::KernelRuntime;
use rylon::sim::sim_rylon_join;
use rylon::table::Table;
use std::sync::Arc;

fn runtime() -> Option<Arc<KernelRuntime>> {
    match KernelRuntime::load_default() {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            eprintln!("skipping AOT integration: {e}");
            None
        }
    }
}

#[test]
fn shuffle_uses_kernel_and_routes_identically() {
    let Some(rt) = runtime() else { return };
    let world = 4;
    // With runtime attached: shuffle stats must report kernel use, and
    // results must match the native run exactly.
    let with_kernel = try_run_workers(world, &CommConfig::default(), Some(rt), move |ctx| {
        let t = paper_table(5_000, 1.0, 70 + ctx.rank() as u64);
        let (out, stats) = rylon::dist::shuffle(ctx, &t, 0)?;
        Ok((out, stats.used_kernel))
    })
    .unwrap();
    let native = try_run_workers(world, &CommConfig::default(), None, move |ctx| {
        let t = paper_table(5_000, 1.0, 70 + ctx.rank() as u64);
        let (out, stats) = rylon::dist::shuffle(ctx, &t, 0)?;
        Ok((out, stats.used_kernel))
    })
    .unwrap();
    for ((kt, kused), (nt, nused)) in with_kernel.iter().zip(&native) {
        assert!(kused, "kernel path not taken despite runtime");
        assert!(!nused);
        assert!(kt.data_equals(nt), "kernel and native shuffles diverge");
    }
}

#[test]
fn sim_join_invariant_to_kernel_path() {
    let Some(rt) = runtime() else { return };
    let chunks = |seed: u64| -> Vec<Table> {
        (0..3).map(|w| paper_table(4_000, 0.9, seed + w as u64)).collect()
    };
    let l = chunks(900);
    let r = chunks(950);
    let cfg = JoinConfig::inner(0, 0);
    let with_kernel =
        sim_rylon_join(&l, &r, &cfg, NetworkProfile::Loopback, Some(&rt)).unwrap();
    let native = sim_rylon_join(&l, &r, &cfg, NetworkProfile::Loopback, None).unwrap();
    assert_eq!(with_kernel.rows_out, native.rows_out);
    assert_eq!(with_kernel.comm_bytes, native.comm_bytes);
}

#[test]
fn kernel_handles_all_block_boundaries() {
    let Some(rt) = runtime() else { return };
    let blocks = rt.block_sizes().to_vec();
    let smallest = blocks[0];
    // Exercise exact-block, off-by-one, multi-block, and tiny sizes.
    let sizes = [
        1usize,
        smallest - 1,
        smallest,
        smallest + 1,
        2 * smallest + 17,
        blocks[blocks.len() - 1] + 3,
    ];
    for n in sizes {
        let keys: Vec<i64> = (0..n as u64)
            .map(|i| i.wrapping_mul(0x9e37_79b9_7f4a_7c15) as i64)
            .collect();
        let ids = rt.hash_partition_ids(&keys, 7).unwrap();
        assert_eq!(ids.len(), n, "size {n}");
        for (k, id) in keys.iter().zip(&ids) {
            assert_eq!(rylon::ops::hash::hash_i64(*k) % 7, *id, "size {n}");
        }
    }
}

#[test]
fn kernel_runtime_is_shareable_across_threads() {
    let Some(rt) = runtime() else { return };
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let rt = rt.clone();
            std::thread::spawn(move || {
                let keys: Vec<i64> = (0..1000).map(|i| (i * 31 + t) as i64).collect();
                rt.hash_partition_ids(&keys, 5).unwrap().len()
            })
        })
        .collect();
    for h in handles {
        assert_eq!(h.join().unwrap(), 1000);
    }
    let stats = rt.stats().unwrap();
    assert!(stats.kernel_calls >= 4);
}
