//! Property tests for the query planner: for randomized dataflow
//! graphs over `paper_table` inputs, the optimized plan's output is
//! **bit-identical** to naive node-by-node execution at parallelism
//! 1/2/7 and world 1/3 — including pipelines above the radix
//! threshold (where pushdown must replay pinned build-side/fan-out
//! decisions) and an already-partitioned pipeline where shuffle
//! elision provably fires (asserted via the executor's stats).

use rylon::coordinator::run_workers;
use rylon::dataflow::Graph;
use rylon::io::generator::{paper_table, SplitMix64};
use rylon::net::CommConfig;
use rylon::ops::aggregate::{AggFn, AggSpec};
use rylon::ops::expr::Expr;
use rylon::ops::join::{JoinConfig, JoinType};
use rylon::plan::ExecStats;
use rylon::table::{DataType, Table};

/// One random comparison atom over a column of the given type.
fn atom(rng: &mut SplitMix64, types: &[DataType]) -> Expr {
    let c = rng.next_below(types.len() as u64) as usize;
    let col = Expr::col(c);
    match types[c] {
        DataType::Int64 => match rng.next_below(3) {
            0 => col.modulo(Expr::lit_i64(2 + rng.next_below(5) as i64)).eq(Expr::lit_i64(0)),
            1 => col.gt(Expr::lit_i64(rng.next_below(200) as i64)),
            _ => col.is_null().not(),
        },
        DataType::Float64 => match rng.next_below(3) {
            0 => col.lt(Expr::lit_f64(rng.next_f64())),
            1 => col.ge(Expr::lit_f64(rng.next_f64() * 0.5)),
            _ => col.add(Expr::lit_f64(0.25)).le(Expr::lit_f64(1.0)),
        },
        DataType::Bool => col.eq(Expr::lit_bool(rng.next_below(2) == 0)),
        DataType::Utf8 => col.ge(Expr::lit_str("m")),
    }
}

fn rand_pred(rng: &mut SplitMix64, types: &[DataType]) -> Expr {
    let mut e = atom(rng, types);
    for _ in 0..rng.next_below(2) {
        let other = atom(rng, types);
        e = if rng.next_below(2) == 0 { e.and(other) } else { e.or(other) };
    }
    e
}

/// Deterministically build a random (but always valid) graph over
/// sources "a" and "b", tracking per-node output types.
fn build_random_graph(seed: u64) -> Graph {
    let mut rng = SplitMix64::new(seed);
    let paper_types = vec![
        DataType::Int64,
        DataType::Float64,
        DataType::Float64,
        DataType::Float64,
    ];
    let mut g = Graph::new();
    let a = g.source("a");
    let b = g.source("b");
    let mut nodes = vec![(a, paper_types.clone()), (b, paper_types)];
    let ops = 3 + rng.next_below(5) as usize;
    for _ in 0..ops {
        let pick = rng.next_below(nodes.len() as u64) as usize;
        let (nid, types) = nodes[pick].clone();
        match rng.next_below(8) {
            0 => {
                let pred = rand_pred(&mut rng, &types);
                nodes.push((g.filter(nid, pred), types));
            }
            1 => {
                // random non-empty projection, possibly reordering
                let keep = 1 + rng.next_below(types.len() as u64) as usize;
                let mut cols = Vec::with_capacity(keep);
                for _ in 0..keep {
                    cols.push(rng.next_below(types.len() as u64) as usize);
                }
                let new_types: Vec<DataType> = cols.iter().map(|&c| types[c]).collect();
                nodes.push((g.project(nid, cols), new_types));
            }
            2 => {
                // numeric derived column (always f64)
                let numeric: Vec<usize> = types
                    .iter()
                    .enumerate()
                    .filter(|(_, t)| matches!(t, DataType::Int64 | DataType::Float64))
                    .map(|(i, _)| i)
                    .collect();
                if numeric.is_empty() {
                    continue;
                }
                let c = numeric[rng.next_below(numeric.len() as u64) as usize];
                let expr = Expr::col(c).add(Expr::lit_f64(0.5));
                let mut new_types = types.clone();
                new_types.push(DataType::Float64);
                nodes.push((g.with_column(nid, "d", expr), new_types));
            }
            3 => {
                let col = rng.next_below(types.len() as u64) as usize;
                nodes.push((g.sort(nid, col), types));
            }
            4 => {
                // join on int64 keys of two candidates
                let pick2 = rng.next_below(nodes.len() as u64) as usize;
                let (nid2, types2) = nodes[pick2].clone();
                let k1 = types.iter().position(|t| *t == DataType::Int64);
                let k2 = types2.iter().position(|t| *t == DataType::Int64);
                let (Some(k1), Some(k2)) = (k1, k2) else { continue };
                let jt = match rng.next_below(3) {
                    0 => JoinType::Inner,
                    1 => JoinType::Left,
                    _ => JoinType::Right,
                };
                let cfg = JoinConfig::new(jt, k1, k2);
                let mut new_types = types.clone();
                new_types.extend(types2.iter().copied());
                nodes.push((g.join(nid, nid2, cfg), new_types));
            }
            5 => {
                // set op over type-equal candidates
                let pick2 = rng.next_below(nodes.len() as u64) as usize;
                let (nid2, types2) = nodes[pick2].clone();
                if types != types2 {
                    continue;
                }
                let out = match rng.next_below(3) {
                    0 => g.union(nid, nid2),
                    1 => g.intersect(nid, nid2),
                    _ => g.difference(nid, nid2),
                };
                nodes.push((out, types));
            }
            6 => {
                let numeric: Vec<usize> = types
                    .iter()
                    .enumerate()
                    .filter(|(_, t)| matches!(t, DataType::Int64 | DataType::Float64))
                    .map(|(i, _)| i)
                    .collect();
                if numeric.is_empty() {
                    continue;
                }
                let key = rng.next_below(types.len() as u64) as usize;
                if types[key] == DataType::Utf8 {
                    continue;
                }
                let vcol = numeric[rng.next_below(numeric.len() as u64) as usize];
                let func = match rng.next_below(4) {
                    0 => AggFn::Count,
                    1 => AggFn::Sum,
                    2 => AggFn::Min,
                    _ => AggFn::Mean,
                };
                let new_types = vec![types[key], DataType::Float64];
                nodes.push((g.group_by(nid, key, vec![AggSpec::new(func, vcol)]), new_types));
            }
            _ => {
                // stacked filters: exercises fusion
                let p1 = rand_pred(&mut rng, &types);
                let p2 = rand_pred(&mut rng, &types);
                let f1 = g.filter(nid, p1);
                nodes.push((g.filter(f1, p2), types));
            }
        }
    }
    // Sink the newest node plus one random earlier node (multi-sink +
    // dead-node coverage).
    g.sink(nodes.last().unwrap().0);
    let extra = rng.next_below(nodes.len() as u64) as usize;
    g.sink(nodes[extra].0);
    g
}

fn sources(rows: usize, seed: u64) -> [(&'static str, Table); 2] {
    [
        ("a", paper_table(rows, 0.6, seed)),
        ("b", paper_table(rows, 0.6, seed ^ 0xBEEF)),
    ]
}

#[test]
fn optimized_equals_naive_randomized_world1() {
    for case in 0..12u64 {
        let g = build_random_graph(0x9A10 + case);
        let srcs = sources(400, 0x11 + case);
        let mut base: Option<Vec<Table>> = None;
        for threads in [1usize, 2, 7] {
            let mut ctx = rylon::ctx::CylonContext::init_local().with_parallelism(threads);
            let naive = g.execute_naive_with(&mut ctx, &srcs).unwrap();
            let opt = g.execute_with(&mut ctx, &srcs).unwrap();
            assert_eq!(naive.len(), opt.len());
            for (k, (n, o)) in naive.iter().zip(&opt).enumerate() {
                assert!(
                    o.data_equals(n),
                    "case {case} threads {threads} sink {k}:\n{}",
                    g.explain_optimized(1, &srcs).unwrap()
                );
            }
            // and identical across thread counts
            if let Some(b) = &base {
                for (x, y) in b.iter().zip(&opt) {
                    assert!(x.data_equals(y), "case {case} thread-variance");
                }
            } else {
                base = Some(opt);
            }
        }
    }
}

#[test]
fn optimized_equals_naive_randomized_world3() {
    let world = 3;
    for case in 0..6u64 {
        let seed = 0x3A10 + case;
        let run = |optimized: bool| -> Vec<Vec<Table>> {
            run_workers(world, &CommConfig::default(), move |ctx| {
                let g = build_random_graph(seed);
                let srcs = sources(200, 0x77 + seed * 10 + ctx.rank() as u64);
                for threads in [1usize, 2] {
                    ctx.set_parallelism(threads);
                    // outputs must not depend on threads either way
                    let r1 = if optimized {
                        g.execute_with(ctx, &srcs).unwrap()
                    } else {
                        g.execute_naive_with(ctx, &srcs).unwrap()
                    };
                    if threads == 2 {
                        return r1;
                    }
                }
                unreachable!()
            })
        };
        let naive = run(false);
        let opt = run(true);
        for (rank, (n, o)) in naive.iter().zip(&opt).enumerate() {
            assert_eq!(n.len(), o.len());
            for (k, (nt, ot)) in n.iter().zip(o).enumerate() {
                assert!(ot.data_equals(nt), "case {case} rank {rank} sink {k}");
            }
        }
    }
}

#[test]
fn pushdown_above_radix_threshold_replays_pinned_decisions() {
    // Inputs big enough that the naive set ops / hash join run the
    // 64-way radix path (12k + 6k > 16Ki rows) while the filtered
    // inputs would not; asymmetric sizes so the join's default build
    // side would flip after filtering. The pinned fan-out and
    // orientation must reproduce the naive order anyway.
    let srcs = [
        ("a", paper_table(12_000, 0.6, 0xAA)),
        ("b", paper_table(6_000, 0.6, 0xBB)),
    ];
    // union → filter (sinks below both sides, pinned fan-out)
    let mut g1 = Graph::new();
    let a = g1.source("a");
    let b = g1.source("b");
    let u = g1.union(a, b);
    let f = g1.filter(u, Expr::col(1).lt(Expr::lit_f64(0.2)));
    g1.sink(f);
    // join (|l| > |r|) → filter on left cols that shrinks l below |r|
    // (pinned orientation), then a projection
    let mut g2 = Graph::new();
    let a2 = g2.source("a");
    let b2 = g2.source("b");
    let p = g2.project(b2, vec![0, 1]); // smaller, narrower right side
    let j = g2.join(a2, p, JoinConfig::inner(0, 0));
    let f2 = g2.filter(j, Expr::col(1).lt(Expr::lit_f64(0.1)));
    let pr = g2.project(f2, vec![0, 1, 5]);
    g2.sink(pr);
    for (name, g) in [("union", g1), ("join", g2)] {
        for threads in [1usize, 7] {
            let mut ctx = rylon::ctx::CylonContext::init_local().with_parallelism(threads);
            let naive = g.execute_naive_with(&mut ctx, &srcs).unwrap();
            let opt = g.execute_with(&mut ctx, &srcs).unwrap();
            assert!(
                opt[0].data_equals(&naive[0]),
                "{name} threads {threads}:\n{}",
                g.explain_optimized(1, &srcs).unwrap()
            );
        }
    }
}

#[test]
fn shuffle_elision_fires_on_partitioned_pipeline() {
    // join establishes hash(c0) at world 3; the downstream group-by on
    // the same key must skip its partial shuffle (the second-stage
    // AllToAll), and a second join on the key must skip its left-side
    // shuffle — both proven via ShuffleStats-derived ExecStats, with
    // per-rank outputs bit-identical to naive execution.
    let world = 3;
    let build = || {
        let mut g = Graph::new();
        let a = g.source("a");
        let b = g.source("b");
        let c = g.source("c");
        let j1 = g.join(a, b, JoinConfig::inner(0, 0));
        let gb = g.group_by(j1, 0, vec![AggSpec::new(AggFn::Sum, 1)]);
        let j2 = g.join(j1, c, JoinConfig::inner(0, 0));
        g.sink(gb);
        g.sink(j2);
        g
    };
    let run = |optimized: bool| -> Vec<(Vec<Table>, ExecStats)> {
        run_workers(world, &CommConfig::default(), move |ctx| {
            ctx.set_optimize(optimized);
            let srcs = [
                ("a", paper_table(200, 0.5, 61 + ctx.rank() as u64)),
                ("b", paper_table(200, 0.5, 71 + ctx.rank() as u64)),
                ("c", paper_table(200, 0.5, 81 + ctx.rank() as u64)),
            ];
            build().execute_with_stats(ctx, &srcs).unwrap()
        })
    };
    let naive = run(false);
    let opt = run(true);
    for (rank, ((nt, ns), (ot, os))) in naive.iter().zip(&opt).enumerate() {
        for (k, (a, b)) in nt.iter().zip(ot).enumerate() {
            assert!(b.data_equals(a), "rank {rank} sink {k}");
        }
        assert_eq!(ns.shuffles_elided, 0, "naive path never elides");
        // group-by partial shuffle + second join's left shuffle
        assert!(
            os.shuffles_elided >= 2,
            "rank {rank}: expected ≥2 elided shuffles, got {os:?}"
        );
        assert!(os.shuffles < ns.shuffles, "elision must reduce real shuffles");
    }
}

#[test]
fn string_predicates_push_through_projections() {
    use rylon::table::Array;
    let t = Table::from_arrays(vec![
        (
            "s",
            Array::Utf8(rylon::table::column::Utf8Array::from_options(&[
                Some("apple"),
                Some("pear"),
                None,
                Some("plum"),
                Some("apple"),
                Some("fig"),
            ])),
        ),
        ("k", Array::from_i64(vec![1, 2, 3, 4, 5, 6])),
        ("v", Array::from_f64(vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6])),
    ])
    .unwrap();
    let mut g = Graph::new();
    let src = g.source("t");
    let p = g.project(src, vec![1, 0]); // reorder: k, s
    let f = g.filter(p, Expr::col(1).ge(Expr::lit_str("pe")).and(Expr::col(1).is_null().not()));
    g.sink(f);
    let srcs = [("t", t)];
    let mut ctx = rylon::ctx::CylonContext::init_local();
    let naive = g.execute_naive_with(&mut ctx, &srcs).unwrap();
    let opt = g.execute_with(&mut ctx, &srcs).unwrap();
    assert!(opt[0].data_equals(&naive[0]));
    assert_eq!(opt[0].num_rows(), 2); // pear, plum
    let plan = g.explain_optimized(1, &srcs).unwrap();
    assert!(plan.contains("predicate pushdown"), "{plan}");
}

#[test]
fn streaming_pipelines_fuse_and_match_naive() {
    // Chain-heavy pipeline: join → filter → with_column → project →
    // sort. The optimized executor fuses the whole middle run into the
    // sort's input scan; output must stay bit-identical to naive
    // node-by-node execution at every thread count and world size.
    let build = || {
        let mut g = Graph::new();
        let a = g.source("a");
        let b = g.source("b");
        let j = g.join(a, b, JoinConfig::inner(0, 0));
        let f = g.filter(j, Expr::col(1).lt(Expr::lit_f64(0.6)));
        let w = g.with_column(f, "d", Expr::col(2).add(Expr::lit_f64(1.0)));
        let p = g.project(w, vec![0, 1, 8]);
        let s = g.sort(p, 1);
        g.sink(s);
        g
    };
    let g = build();
    let srcs = sources(3_000, 0x51);
    let mut base: Option<Vec<Table>> = None;
    for threads in [1usize, 2, 7] {
        let mut ctx = rylon::ctx::CylonContext::init_local().with_parallelism(threads);
        let naive = g.execute_naive_with(&mut ctx, &srcs).unwrap();
        let (opt, stats) = g.execute_with_stats(&mut ctx, &srcs).unwrap();
        assert!(opt[0].data_equals(&naive[0]), "threads {threads}");
        assert!(
            stats.nodes_streamed >= 3,
            "filter/with_column/project all fuse, threads {threads}: {stats:?}"
        );
        assert!(stats.peak_rows > 0 && stats.peak_bytes > 0, "threads {threads}");
        if let Some(bs) = &base {
            assert!(bs[0].data_equals(&opt[0]), "thread-variance at {threads}");
        } else {
            base = Some(opt);
        }
    }
    // World 3: morsel boundaries derive only from each rank's input, so
    // fusion stays rank-deterministic and bit-identical to naive.
    let world = 3;
    let run = |optimized: bool| -> Vec<(Vec<Table>, ExecStats)> {
        run_workers(world, &CommConfig::default(), move |ctx| {
            ctx.set_optimize(optimized);
            let srcs = sources(600, 0x51 + ctx.rank() as u64);
            build().execute_with_stats(ctx, &srcs).unwrap()
        })
    };
    let naive = run(false);
    let opt = run(true);
    for (rank, ((nt, _), (ot, os))) in naive.iter().zip(&opt).enumerate() {
        assert!(ot[0].data_equals(&nt[0]), "rank {rank}");
        assert!(os.nodes_streamed >= 3, "rank {rank}: {os:?}");
    }
}

#[test]
fn memory_budget_forces_spill_and_stays_bit_identical() {
    // Inputs above the radix threshold (12k + 9k > 16Ki) so the
    // budgeted hash join takes the spilling Grace path, with a sort
    // breaker downstream that must spill too. A 64 KiB budget is far
    // below the ~700 KiB working set, so both breakers go external —
    // and the output must not change by a bit.
    let mut g = Graph::new();
    let a = g.source("a");
    let b = g.source("b");
    let j = g.join(a, b, JoinConfig::inner(0, 0));
    let s = g.sort(j, 1);
    g.sink(s);
    let srcs = [
        ("a", paper_table(12_000, 0.6, 0xC1)),
        ("b", paper_table(9_000, 0.6, 0xC2)),
    ];
    let mut ctx = rylon::ctx::CylonContext::init_local().with_parallelism(2);
    let (want, no_spill) = g.execute_with_stats(&mut ctx, &srcs).unwrap();
    assert_eq!(no_spill.spills, 0);
    assert_eq!(no_spill.spill_bytes, 0);
    for threads in [1usize, 2, 7] {
        let mut ctx = rylon::ctx::CylonContext::init_local()
            .with_parallelism(threads)
            .with_memory_budget(64 * 1024);
        let (got, stats) = g.execute_with_stats(&mut ctx, &srcs).unwrap();
        assert!(got[0].data_equals(&want[0]), "threads {threads}");
        assert!(stats.spills >= 2, "join and sort both spill, threads {threads}: {stats:?}");
        assert!(stats.spill_bytes > 0, "threads {threads}");
    }
}

#[test]
fn diamond_with_breaker_and_streaming_consumers_matches_naive() {
    // The filter fans out to two consumers — a sort (pipeline breaker)
    // and an identity projection that streams into the union's input
    // scan. The fan-out node itself must materialize exactly once
    // (multi-consumer nodes never stream), while the projection fuses.
    let mut g = Graph::new();
    let t = g.source("t");
    let f = g.filter(t, Expr::col(0).modulo(Expr::lit_i64(3)).eq(Expr::lit_i64(0)));
    let srt = g.sort(f, 1);
    let p = g.project(f, vec![0, 1, 2, 3]);
    let u = g.union(srt, p);
    g.sink(u);
    let srcs = [("t", paper_table(2_000, 0.7, 0xD7))];
    for threads in [1usize, 2, 7] {
        let mut ctx = rylon::ctx::CylonContext::init_local().with_parallelism(threads);
        let naive = g.execute_naive_with(&mut ctx, &srcs).unwrap();
        let (opt, stats) = g.execute_with_stats(&mut ctx, &srcs).unwrap();
        assert!(opt[0].data_equals(&naive[0]), "threads {threads}");
        assert!(stats.nodes_streamed >= 1, "projection fuses, threads {threads}: {stats:?}");
    }
}

#[test]
fn invalid_graphs_error_on_both_paths() {
    // out-of-range predicate column: optimizer must fall back and the
    // error must surface exactly as it does naively
    let mut g = Graph::new();
    let s = g.source("t");
    let f = g.filter(s, Expr::col(99).is_null());
    g.sink(f);
    let srcs = [("t", paper_table(10, 1.0, 1))];
    let mut ctx = rylon::ctx::CylonContext::init_local();
    assert!(g.execute_naive_with(&mut ctx, &srcs).is_err());
    assert!(g.execute_with(&mut ctx, &srcs).is_err());
    // a dead ill-typed node also errors on both paths
    let mut g2 = Graph::new();
    let s2 = g2.source("t");
    let _dead = g2.filter(s2, Expr::col(0).and(Expr::col(1)));
    let ok = g2.project(s2, vec![0]);
    g2.sink(ok);
    assert!(g2.execute_naive_with(&mut ctx, &srcs).is_err());
    assert!(g2.execute_with(&mut ctx, &srcs).is_err());
}
