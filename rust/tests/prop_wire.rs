//! Property tests for the zero-copy wire path (PR 5): serial ≡ parallel
//! **byte-identically on the wire** and **bit-identically in memory** at
//! every thread count; the concat-on-decode shuffle equals
//! decode-then-concat; and corrupted buffers (truncated / bad magic /
//! stale version) error cleanly instead of panicking.

use rylon::coordinator::run_workers;
use rylon::net::serialize::{
    concat_decode_parts, deserialize_table, deserialize_table_par, serialize_table_par,
    table_wire_size, WirePart, WIRE_VERSION,
};
use rylon::net::CommConfig;
use rylon::table::take::concat_tables;
use rylon::table::{Array, Table, Utf8Array};

const MORSEL: usize = 1 << 16;

/// Adversarial table shapes: null-heavy, all-null, empty-with-validity,
/// Utf8-heavy (empty / long / multibyte strings), and 64Ki±1 row
/// boundaries.
fn shapes() -> Vec<(String, Table)> {
    let mut out: Vec<(String, Table)> = Vec::new();

    // Null-heavy: ~80% nulls across every nullable type.
    let rows = 5_000;
    let null_heavy = Table::from_arrays(vec![
        (
            "i",
            Array::from_i64_opts(
                (0..rows).map(|r| (r % 5 == 0).then_some(r as i64 - 17)).collect(),
            ),
        ),
        (
            "f",
            Array::from_f64_opts(
                (0..rows)
                    .map(|r| match r % 5 {
                        0 => Some(f64::NAN),
                        1 => Some(r as f64 * 0.25 - 3.0),
                        _ => None,
                    })
                    .collect(),
            ),
        ),
        (
            "s",
            Array::Utf8(Utf8Array::from_options(
                &(0..rows)
                    .map(|r| (r % 5 == 2).then(|| format!("v{r}")))
                    .collect::<Vec<_>>(),
            )),
        ),
        ("b", Array::from_bools((0..rows).map(|r| r % 3 == 0).collect())),
    ])
    .unwrap();
    out.push(("null_heavy".into(), null_heavy));

    // All-null columns crossing a validity word boundary.
    let rows = 70;
    let all_null = Table::from_arrays(vec![
        ("i", Array::from_i64_opts(vec![None; rows])),
        ("f", Array::from_f64_opts(vec![None; rows])),
        ("s", Array::Utf8(Utf8Array::from_options(&vec![None::<&str>; rows]))),
    ])
    .unwrap();
    out.push(("all_null".into(), all_null));

    // Zero rows, validity-carrying columns.
    let empty_with_validity = Table::from_arrays(vec![
        ("i", Array::from_i64_opts(vec![])),
        ("s", Array::Utf8(Utf8Array::from_options::<&str>(&[]))),
    ])
    .unwrap();
    out.push(("empty_with_validity".into(), empty_with_validity));

    // Utf8-heavy: empty strings, multibyte, long values, sparse nulls.
    let rows = 3_000;
    let strings: Vec<Option<String>> = (0..rows)
        .map(|r| match r % 7 {
            0 => None,
            1 => Some(String::new()),
            2 => Some("wörld-ü-∞".to_string()),
            3 => Some("x".repeat(r % 97)),
            _ => Some(format!("row-{r}")),
        })
        .collect();
    let utf8_heavy = Table::from_arrays(vec![
        ("a", Array::Utf8(Utf8Array::from_options(&strings))),
        (
            "b",
            Array::from_strs(&(0..rows).map(|r| format!("k{}", r % 11)).collect::<Vec<_>>()),
        ),
    ])
    .unwrap();
    out.push(("utf8_heavy".into(), utf8_heavy));

    // 64Ki±1 morsel boundaries with mixed types and nulls.
    for rows in [MORSEL - 1, MORSEL, MORSEL + 1] {
        let t = rylon::io::generator::random_table(rows, 0xB0DA + rows as u64);
        out.push((format!("boundary_{rows}"), t));
    }
    out
}

#[test]
fn wire_bytes_byte_identical_and_tables_bit_identical_at_every_parallelism() {
    for (name, t) in shapes() {
        let serial_bytes = serialize_table_par(&t, 1);
        assert_eq!(serial_bytes.len(), table_wire_size(&t), "{name}: exact pre-sizing");
        for threads in [2usize, 7] {
            assert_eq!(
                serialize_table_par(&t, threads),
                serial_bytes,
                "{name}: wire bytes differ at threads={threads}"
            );
        }
        let serial = deserialize_table(&serial_bytes).unwrap();
        assert!(serial.data_equals(&t), "{name}: roundtrip");
        assert_eq!(serial.schema(), t.schema(), "{name}: schema roundtrip");
        for threads in [2usize, 7] {
            let par = deserialize_table_par(&serial_bytes, threads).unwrap();
            assert!(par.data_equals(&serial), "{name}: decode differs at threads={threads}");
            assert_eq!(par.schema(), serial.schema(), "{name}: threads={threads}");
        }
    }
}

#[test]
fn concat_on_decode_equals_decode_then_concat() {
    // Type-equal parts with different names, sizes, and validity
    // presence — including an empty part and a no-validity part, with
    // one part kept as a loopback table (as the shuffle does).
    let parts: Vec<Table> = vec![
        rylon::io::generator::random_table(210, 0xA),
        rylon::io::generator::random_table(0, 0xB),
        Table::from_arrays(vec![
            ("k2", Array::from_i64((0..57).collect())),
            ("f2", Array::from_f64((0..57).map(|x| x as f64 / 3.0).collect())),
            (
                "s2",
                Array::from_strs(&(0..57).map(|x| format!("p{x}")).collect::<Vec<_>>()),
            ),
            ("b2", Array::from_bools(vec![true; 57])),
        ])
        .unwrap(),
        rylon::io::generator::random_table(4097, 0xC),
    ];
    let wires: Vec<Vec<u8>> = parts.iter().map(|p| serialize_table_par(p, 1)).collect();
    for loopback in 0..parts.len() {
        let decoded: Vec<Table> = wires.iter().map(|b| deserialize_table(b).unwrap()).collect();
        let mut oracle_in: Vec<&Table> = decoded.iter().collect();
        oracle_in[loopback] = &parts[loopback];
        let want = concat_tables(&oracle_in).unwrap();
        let srcs: Vec<WirePart<'_>> = wires
            .iter()
            .enumerate()
            .map(|(i, b)| {
                if i == loopback {
                    WirePart::Table(&parts[i])
                } else {
                    WirePart::Bytes(b.as_slice())
                }
            })
            .collect();
        for threads in [1usize, 2, 7] {
            let got = concat_decode_parts(&srcs, threads).unwrap();
            assert!(got.data_equals(&want), "loopback={loopback} threads={threads}");
            assert_eq!(got.schema(), want.schema(), "loopback={loopback} threads={threads}");
        }
    }
}

#[test]
fn shuffle_bit_identical_at_every_parallelism_and_world() {
    // The concat-on-decode shuffle end to end through the distributed
    // layer: outputs must be a pure function of the input at world 1
    // and 3, whatever each rank's thread budget is.
    let run = |world: usize, threads: usize| -> Vec<Table> {
        run_workers(world, &CommConfig::default(), move |ctx| {
            ctx.set_parallelism(threads);
            let t = rylon::io::generator::random_table(400, 0x5117 + ctx.rank() as u64);
            rylon::dist::shuffle(ctx, &t, 0).unwrap().0
        })
    };
    for world in [1usize, 3] {
        let base = run(world, 1);
        for threads in [2usize, 7] {
            let got = run(world, threads);
            for (rank, (b, g)) in base.iter().zip(&got).enumerate() {
                assert!(
                    g.data_equals(b),
                    "world={world} threads={threads} rank={rank}"
                );
                assert_eq!(g.schema(), b.schema(), "world={world} threads={threads}");
            }
        }
    }
}

#[test]
fn truncated_buffers_error_cleanly() {
    let t = rylon::io::generator::random_table(128, 0x7E57);
    let bytes = serialize_table_par(&t, 1);
    // Every strict prefix must error (never panic, never succeed):
    // cuts inside the fixed header, the extents index, and each block.
    for cut in [0, 3, 4, 11, 19, 20, 35, bytes.len() / 3, bytes.len() / 2, bytes.len() - 1] {
        let r = deserialize_table(&bytes[..cut]);
        assert!(r.is_err(), "cut={cut} must error");
        for threads in [2usize, 7] {
            assert!(deserialize_table_par(&bytes[..cut], threads).is_err(), "cut={cut}");
        }
    }
}

#[test]
fn bad_magic_and_stale_version_error_cleanly() {
    let t = rylon::io::generator::random_table(16, 0xBAD);
    let good = serialize_table_par(&t, 1);

    let mut bad_magic = good.clone();
    bad_magic[0] ^= 0xFF;
    assert!(deserialize_table(&bad_magic).is_err());

    // A version-1 buffer (or any other stale/future version) is
    // rejected with an error that names the version mismatch.
    for stale in [0u32, 1, WIRE_VERSION + 1, u32::MAX] {
        let mut b = good.clone();
        b[4..8].copy_from_slice(&stale.to_le_bytes());
        let err = deserialize_table(&b).unwrap_err().to_string();
        assert!(err.contains("version"), "stale={stale}: unhelpful error: {err}");
    }

    // Corrupt extents (block claimed past the end) error cleanly too,
    // through both the plain decoder and concat-on-decode.
    let mut huge_extent = good.clone();
    huge_extent[20..28].copy_from_slice(&u64::MAX.to_le_bytes());
    assert!(deserialize_table(&huge_extent).is_err());
    assert!(concat_decode_parts(&[WirePart::Bytes(&huge_extent)], 2).is_err());
    assert!(concat_decode_parts(
        &[WirePart::Table(&t), WirePart::Bytes(&good[..good.len() - 1])],
        2
    )
    .is_err());
}
