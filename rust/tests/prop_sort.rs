//! Sort determinism property tests: the typed morsel-parallel sort
//! engine must produce **bit-identical** tables at `parallelism ∈
//! {1, 2, 7}` for local `sort`, `external_sort`, and `dist_sort` —
//! including null-heavy and all-null key columns, NaN/±0.0 floats
//! (IEEE total order), duplicate keys (stable `(key, row)` ties), and
//! the serial/parallel and morsel boundary sizes (16Ki±1, 64Ki±1).
//!
//! The reference oracle is the seed's `cmp_cells` comparator with the
//! stable row tie-break appended — the typed u64 encodings and `&str`
//! comparators must order exactly like it.
//!
//! proptest is not vendored in this offline image; as in the sibling
//! suites, a deterministic seed sweep over adversarial generators
//! stands in.

use rylon::coordinator::run_workers;
use rylon::dist::dist_sort;
use rylon::dist::testutil::{gather, row_multiset};
use rylon::external::{external_sort, external_sort_par};
use rylon::io::generator::{paper_table_with_keyspace, random_table, SplitMix64};
use rylon::net::CommConfig;
use rylon::ops::parallel::MORSEL_ROWS;
use rylon::ops::set_parallelism;
use rylon::ops::sort::{cmp_cells, is_sorted, sort, sort_par, SORT_PAR_MIN_ROWS};
use rylon::table::take::take_table;
use rylon::table::{Array, BoolArray, Table};

const THREADS: [usize; 3] = [1, 2, 7];

/// Oracle: the stable sort contract expressed through the reference
/// comparator — ascending by `cmp_cells`, ties by original row index.
fn oracle_sort(t: &Table, col: usize) -> Table {
    let a = t.column(col).as_ref();
    let mut idx: Vec<usize> = (0..t.num_rows()).collect();
    idx.sort_by(|&i, &j| cmp_cells(a, i, j).then(i.cmp(&j)));
    take_table(t, &idx)
}

/// `sort_par` must equal the oracle bit-for-bit at every thread count.
fn assert_sort_contract(t: &Table, col: usize) {
    let want = oracle_sort(t, col);
    for threads in THREADS {
        let got = sort_par(t, col, threads).unwrap();
        assert!(got.data_equals(&want), "col {col} threads={threads}");
        assert!(is_sorted(&got, col), "col {col} threads={threads}");
    }
}

#[test]
fn local_sort_matches_stable_oracle_all_types() {
    let mut rng = SplitMix64::new(0x5027_0001);
    for _case in 0..16usize {
        let rows = rng.next_below(300) as usize;
        let t = random_table(rows, rng.next_u64());
        // Columns: i64 w/ nulls, f64 w/ nulls+NaN, utf8 (dup-heavy),
        // bool (two-value keys = maximal duplication).
        for col in 0..t.num_columns() {
            assert_sort_contract(&t, col);
        }
    }
}

#[test]
fn float_edge_cases_follow_ieee_total_order() {
    let neg_nan = f64::from_bits(f64::NAN.to_bits() | (1u64 << 63));
    let t = Table::from_arrays(vec![
        (
            "k",
            Array::from_f64_opts(vec![
                Some(f64::NAN),
                Some(0.0),
                None,
                Some(-0.0),
                Some(f64::INFINITY),
                Some(neg_nan),
                None,
                Some(f64::NEG_INFINITY),
                Some(1.0),
                Some(-1.0),
            ]),
        ),
        ("row", Array::from_i64((0..10).collect())),
    ])
    .unwrap();
    assert_sort_contract(&t, 0);
    let s = sort(&t, 0).unwrap();
    let k = s.column(0).as_f64().unwrap();
    // nulls (rows 2, 6 in order), then -NaN, -inf, -1, -0.0, +0.0, 1, +inf, +NaN.
    assert!(!k.is_valid(0) && !k.is_valid(1));
    let r = s.column(1).as_i64().unwrap();
    assert_eq!((r.value(0), r.value(1)), (2, 6), "null ties keep row order");
    assert!(k.value(2).is_nan() && k.value(2).is_sign_negative());
    assert_eq!(k.value(3), f64::NEG_INFINITY);
    assert_eq!(k.value(4), -1.0);
    assert_eq!(k.value(5).to_bits(), (-0.0f64).to_bits(), "-0.0 before +0.0");
    assert_eq!(k.value(6).to_bits(), 0.0f64.to_bits());
    assert_eq!(k.value(7), 1.0);
    assert_eq!(k.value(8), f64::INFINITY);
    assert!(k.value(9).is_nan() && k.value(9).is_sign_positive());
}

#[test]
fn bool_keys_with_nulls_follow_contract() {
    // random_table's bool column carries no validity, so pin the
    // null-bearing bool path (rank encoding + null split) explicitly.
    let vals: Vec<Option<bool>> = (0..300)
        .map(|i| match i % 5 {
            0 => None,
            1 | 2 => Some(true),
            _ => Some(false),
        })
        .collect();
    let t = Table::from_arrays(vec![
        ("k", Array::Bool(BoolArray::from_options(vals))),
        ("row", Array::from_i64((0..300).collect())),
    ])
    .unwrap();
    assert_sort_contract(&t, 0);
}

#[test]
fn all_null_column_preserves_row_order() {
    for rows in [0usize, 1, 65, 130] {
        let t = Table::from_arrays(vec![
            ("k", Array::from_i64_opts(vec![None; rows])),
            ("v", Array::from_i64((0..rows as i64).collect())),
        ])
        .unwrap();
        for threads in THREADS {
            let s = sort_par(&t, 0, threads).unwrap();
            // All-equal (null) keys: stable ties mean identity order.
            assert!(s.data_equals(&t), "rows={rows} threads={threads}");
        }
    }
}

#[test]
fn boundary_sizes_bit_identical_and_stable() {
    // 16Ki±1 (the seed's threshold family, firmly on the serial path)
    // and the true serial/parallel cut-over at one 64Ki morsel
    // (SORT_PAR_MIN_ROWS), ±1 — the exact sizes where the engine
    // switches shape. Keys are duplicate heavy (keyspace = rows/16) so
    // ties cross every boundary.
    assert_eq!(SORT_PAR_MIN_ROWS, MORSEL_ROWS, "docs below assume this");
    let sizes = [
        (1 << 14) - 1,
        1 << 14,
        (1 << 14) + 1,
        MORSEL_ROWS - 1,
        MORSEL_ROWS,
        MORSEL_ROWS + 1,
    ];
    for (i, &n) in sizes.iter().enumerate() {
        let t = paper_table_with_keyspace(n, (n as u64 / 16).max(1), 0xB0 + i as u64);
        let want = oracle_sort(&t, 0);
        for threads in THREADS {
            let got = sort_par(&t, 0, threads).unwrap();
            assert!(got.data_equals(&want), "n={n} threads={threads}");
        }
    }
}

#[test]
fn splitter_merge_duplicate_heavy_bit_identical() {
    // PR 10: `merge_runs` is now splitter-partitioned when threads > 1.
    // Adversarial inputs for that path: multiple sorted runs (n >
    // MORSEL_ROWS so the local sort produces >1 run) whose keys are so
    // duplicate-heavy that every splitter lands inside a giant
    // equivalence class — the upper-bound cut rule is what keeps ties
    // from straddling a range boundary. 64Ki±1 pins the exact sizes
    // where the run shapes change; keyspace 1 makes the whole column
    // one tie class.
    let sizes = [MORSEL_ROWS - 1, MORSEL_ROWS, MORSEL_ROWS + 1, 2 * MORSEL_ROWS + 1];
    for (i, &n) in sizes.iter().enumerate() {
        for key_space in [1u64, 2, 16] {
            let t = paper_table_with_keyspace(n, key_space, 0xD0D0 + i as u64);
            let want = sort_par(&t, 0, 1).unwrap();
            assert!(is_sorted(&want, 0), "n={n} ks={key_space}");
            for threads in THREADS {
                let got = sort_par(&t, 0, threads).unwrap();
                assert!(
                    got.data_equals(&want),
                    "n={n} ks={key_space} threads={threads}"
                );
            }
        }
    }
}

#[test]
fn utf8_keys_across_morsel_boundary() {
    // String keys big enough to split into two morsel runs, with heavy
    // duplication so the run merge exercises stable ties.
    let n = MORSEL_ROWS + 101;
    let mut rng = SplitMix64::new(0x57F8);
    let strs: Vec<String> = (0..n)
        .map(|_| {
            let len = rng.next_below(4) as usize;
            (0..len)
                .map(|_| char::from(b'a' + rng.next_below(3) as u8))
                .collect()
        })
        .collect();
    let t = Table::from_arrays(vec![
        ("k", Array::from_strs(&strs)),
        ("row", Array::from_i64((0..n as i64).collect())),
    ])
    .unwrap();
    let serial = sort_par(&t, 0, 1).unwrap();
    assert!(is_sorted(&serial, 0));
    for threads in [2usize, 7] {
        assert!(sort_par(&t, 0, threads).unwrap().data_equals(&serial), "threads={threads}");
    }
    // Spot-check stability on the serial result.
    let k = serial.column(0).as_utf8().unwrap();
    let r = serial.column(1).as_i64().unwrap();
    for i in 1..n {
        if k.value(i - 1) == k.value(i) {
            assert!(r.value(i - 1) < r.value(i), "unstable utf8 tie at {i}");
        }
    }
}

#[test]
fn external_sort_bit_identical_and_equals_in_memory() {
    let t = random_table(2_500, 0xE5077);
    for col in [0usize, 1, 2] {
        let want = sort_par(&t, col, 1).unwrap();
        for threads in THREADS {
            let got = external_sort_par(&t, col, 223, threads).unwrap();
            assert!(got.data_equals(&want), "col {col} threads={threads}");
        }
    }
    // The process-knob convenience wrapper routes through the same path.
    set_parallelism(2);
    let got = external_sort(&t, 0, 301).unwrap();
    set_parallelism(0);
    assert!(got.data_equals(&sort_par(&t, 0, 1).unwrap()));
}

#[test]
fn dist_sort_bit_identical_across_worker_parallelism() {
    let world = 3;
    let run = |threads: usize| {
        run_workers(world, &CommConfig::default(), move |ctx| {
            ctx.set_parallelism(threads);
            let t = random_table(150, 0xD157 + ctx.rank() as u64);
            // i64 w/ nulls, f64 w/ NaN + nulls, utf8 — all three key
            // shapes through sample, route, shuffle, and local sort.
            let a = dist_sort(ctx, &t, 0).unwrap().0;
            let b = dist_sort(ctx, &t, 1).unwrap().0;
            let c = dist_sort(ctx, &t, 2).unwrap().0;
            (t, a, b, c)
        })
    };
    let serial = run(1);
    for threads in [2usize, 7] {
        let par = run(threads);
        for (rank, ((_, sa, sb, sc), (_, pa, pb, pc))) in
            serial.iter().zip(&par).enumerate()
        {
            assert!(pa.data_equals(sa), "rank {rank} col 0 threads={threads}");
            assert!(pb.data_equals(sb), "rank {rank} col 1 threads={threads}");
            assert!(pc.data_equals(sc), "rank {rank} col 2 threads={threads}");
        }
    }
    // And the serial baseline is a correct global sort: rank ranges in
    // order, rows conserved.
    let ins = gather(serial.iter().map(|(t, ..)| t.clone()).collect());
    for (col, pick) in [(0usize, 0usize), (1, 1), (2, 2)] {
        let outs: Vec<Table> = serial
            .iter()
            .map(|(_, a, b, c)| [a, b, c][pick].clone())
            .collect();
        let global = gather(outs);
        assert!(is_sorted(&global, col), "col {col}");
        assert_eq!(row_multiset(&global), row_multiset(&ins), "col {col}");
    }
}

#[test]
fn dist_sort_all_null_keys_route_identically() {
    let world = 3;
    let run = |threads: usize| {
        run_workers(world, &CommConfig::default(), move |ctx| {
            ctx.set_parallelism(threads);
            let rows = 40 + 10 * ctx.rank();
            let t = Table::from_arrays(vec![
                ("k", Array::from_i64_opts(vec![None; rows])),
                (
                    "v",
                    Array::from_i64((0..rows as i64).map(|i| i + ctx.rank() as i64).collect()),
                ),
            ])
            .unwrap();
            dist_sort(ctx, &t, 0).unwrap().0
        })
    };
    let serial = run(1);
    assert_eq!(serial.iter().map(|t| t.num_rows()).sum::<usize>(), 40 + 50 + 60);
    for threads in [2usize, 7] {
        let par = run(threads);
        for (s, p) in serial.iter().zip(&par) {
            assert!(p.data_equals(s), "threads={threads}");
        }
    }
}
