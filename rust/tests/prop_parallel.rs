//! Serial/parallel equivalence property tests: every morsel-parallel
//! operator must produce **bit-identical** tables at `parallelism ∈
//! {1, 2, 7}` — the determinism contract of `rylon::ops::parallel` —
//! including on null-heavy and all-null key columns, across the radix
//! join threshold, and through the distributed shuffle path.
//!
//! proptest is not vendored in this offline image; as in the sibling
//! suites, a deterministic seed sweep over adversarial generators
//! stands in.

use rylon::coordinator::run_workers;
use rylon::io::generator::{paper_table, random_table, SplitMix64};
use rylon::net::CommConfig;
use rylon::ops::aggregate::{group_by_par, AggFn, AggSpec};
use rylon::ops::hash::{hash_cell, hash_column, hash_row, hash_rows};
use rylon::ops::join::{
    join, join_par, nested_loop_join, JoinAlgorithm, JoinConfig, JoinType, RADIX_MIN_ROWS,
};
use rylon::ops::partition::{
    partition_by_ids_par, partition_ids_by_key_par, partition_ids_by_row_par,
};
use rylon::table::pretty::cell_to_string;
use rylon::table::take::{take_table, take_table_opt, take_table_opt_par, take_table_par};
use rylon::table::{Array, Table};
use std::collections::BTreeMap;

const THREADS: [usize; 3] = [1, 2, 7];

fn row_multiset(t: &Table) -> BTreeMap<String, usize> {
    let mut m = BTreeMap::new();
    for r in 0..t.num_rows() {
        let key = (0..t.num_columns())
            .map(|c| cell_to_string(t.column(c), r))
            .collect::<Vec<_>>()
            .join("\u{1}");
        *m.entry(key).or_insert(0) += 1;
    }
    m
}

/// All-null key column plus a payload, the degenerate case the radix
/// split must route through the null-sentinel hash.
fn all_null_keys(rows: usize) -> Table {
    Table::from_arrays(vec![
        ("k", Array::from_i64_opts(vec![None; rows])),
        ("v", Array::from_f64((0..rows).map(|i| i as f64).collect())),
    ])
    .unwrap()
}

#[test]
fn join_identical_at_every_parallelism() {
    let mut rng = SplitMix64::new(0x9A12A11E1);
    for case in 0..24usize {
        let l = random_table(rng.next_below(60) as usize, rng.next_u64());
        let r = random_table(rng.next_below(60) as usize, rng.next_u64());
        let jt = [JoinType::Inner, JoinType::Left, JoinType::Right, JoinType::FullOuter]
            [case % 4];
        let cfg = JoinConfig::new(jt, 0, 0);
        let serial = join_par(&l, &r, &cfg, 1).unwrap();
        for threads in THREADS {
            let par = join_par(&l, &r, &cfg, threads).unwrap();
            assert!(par.data_equals(&serial), "case {case}: {jt:?} threads={threads}");
        }
        // And the canonical order still carries the right multiset.
        let want = nested_loop_join(&l, &r, &cfg).unwrap();
        assert_eq!(row_multiset(&serial), row_multiset(&want), "case {case}");
    }
}

#[test]
fn join_identical_across_radix_threshold() {
    // Big enough that build + probe crosses RADIX_MIN_ROWS, so the
    // 64-way radix path runs and must agree with itself at every
    // thread count and with the sort join's multiset.
    let rows = RADIX_MIN_ROWS;
    let l = paper_table(rows, 0.5, 0xA);
    let r = paper_table(rows, 0.5, 0xB);
    for jt in [JoinType::Inner, JoinType::FullOuter] {
        let cfg = JoinConfig::new(jt, 0, 0);
        let serial = join_par(&l, &r, &cfg, 1).unwrap();
        for threads in [2usize, 7] {
            assert!(join_par(&l, &r, &cfg, threads).unwrap().data_equals(&serial), "{jt:?}");
        }
        let sorted = join(&l, &r, &cfg.with_algorithm(JoinAlgorithm::Sort)).unwrap();
        assert_eq!(row_multiset(&serial), row_multiset(&sorted), "{jt:?}");
    }
}

#[test]
fn join_all_null_keys_identical_and_correct() {
    let l = all_null_keys(97);
    let r = all_null_keys(41);
    for jt in [JoinType::Inner, JoinType::Left, JoinType::Right, JoinType::FullOuter] {
        let cfg = JoinConfig::new(jt, 0, 0);
        let serial = join_par(&l, &r, &cfg, 1).unwrap();
        for threads in THREADS {
            assert!(join_par(&l, &r, &cfg, threads).unwrap().data_equals(&serial), "{jt:?}");
        }
        let want = match jt {
            JoinType::Inner => 0,
            JoinType::Left => 97,
            JoinType::Right => 41,
            JoinType::FullOuter => 138,
        };
        assert_eq!(serial.num_rows(), want, "{jt:?}");
    }
}

#[test]
fn group_by_identical_at_every_parallelism() {
    let aggs = [
        AggSpec::new(AggFn::Sum, 1),
        AggSpec::new(AggFn::Count, 1),
        AggSpec::new(AggFn::Min, 1),
        AggSpec::new(AggFn::Max, 1),
        AggSpec::new(AggFn::Mean, 1),
    ];
    let mut rng = SplitMix64::new(0x66B);
    for case in 0..12 {
        let t = random_table(rng.next_below(200) as usize, rng.next_u64());
        let serial = group_by_par(&t, 0, &aggs, 1).unwrap();
        for threads in THREADS {
            assert!(
                group_by_par(&t, 0, &aggs, threads).unwrap().data_equals(&serial),
                "case {case} threads={threads}"
            );
        }
    }
    // All-null key column: one group, identical everywhere.
    let t = all_null_keys(50);
    let serial = group_by_par(&t, 0, &aggs, 1).unwrap();
    assert_eq!(serial.num_rows(), 1);
    for threads in THREADS {
        assert!(group_by_par(&t, 0, &aggs, threads).unwrap().data_equals(&serial));
    }
}

#[test]
fn partition_routing_identical_and_contractual() {
    let mut rng = SplitMix64::new(0x9A97);
    for _ in 0..10 {
        let t = random_table(rng.next_below(150) as usize, rng.next_u64());
        for p in [1usize, 2, 7] {
            let key1 = partition_ids_by_key_par(&t, 0, p, 1).unwrap();
            let row1 = partition_ids_by_row_par(&t, p, 1).unwrap();
            for threads in THREADS {
                assert_eq!(partition_ids_by_key_par(&t, 0, p, threads).unwrap(), key1);
                assert_eq!(partition_ids_by_row_par(&t, p, threads).unwrap(), row1);
            }
            // The routing contract the golden-hash suite pins: ids are
            // the null-aware cell hash (resp. row hash) mod p.
            let key_col = t.column(0).as_ref();
            for i in 0..t.num_rows() {
                assert_eq!(key1[i], hash_cell(key_col, i) % p as u32);
                assert_eq!(row1[i], hash_row(&t, i) % p as u32);
            }
            let serial_parts = partition_by_ids_par(&t, &key1, p, 1).unwrap();
            for threads in THREADS {
                let parts = partition_by_ids_par(&t, &key1, p, threads).unwrap();
                for (a, b) in parts.iter().zip(&serial_parts) {
                    assert!(a.data_equals(b));
                }
            }
        }
    }
}

#[test]
fn columnar_hashes_match_scalar_oracles() {
    let t = random_table(300, 0xC01);
    for c in t.columns() {
        let serial = hash_column(c, 1);
        for threads in THREADS {
            assert_eq!(hash_column(c, threads), serial);
        }
        for (i, &h) in serial.iter().enumerate() {
            assert_eq!(h, hash_cell(c, i));
        }
    }
    let rows = hash_rows(&t, 1);
    for threads in THREADS {
        assert_eq!(hash_rows(&t, threads), rows);
    }
    for (i, &h) in rows.iter().enumerate() {
        assert_eq!(h, hash_row(&t, i));
    }
}

#[test]
fn take_identical_at_every_parallelism() {
    let t = random_table(120, 0x7A1E);
    let mut rng = SplitMix64::new(0x7A2E);
    let idx: Vec<usize> = (0..200).map(|_| rng.next_below(120) as usize).collect();
    let opt_idx: Vec<Option<usize>> = (0..200)
        .map(|_| {
            if rng.next_below(5) == 0 {
                None
            } else {
                Some(rng.next_below(120) as usize)
            }
        })
        .collect();
    let serial = take_table(&t, &idx);
    let serial_opt = take_table_opt(&t, &opt_idx);
    for threads in THREADS {
        assert!(take_table_par(&t, &idx, threads).data_equals(&serial));
        assert!(take_table_opt_par(&t, &opt_idx, threads).data_equals(&serial_opt));
    }
}

#[test]
fn shuffle_outputs_identical_at_every_worker_parallelism() {
    let run = |threads: usize| {
        run_workers(3, &CommConfig::default(), move |ctx| {
            ctx.set_parallelism(threads);
            let t = random_table(80, 0x5EED + ctx.rank() as u64);
            let key = rylon::dist::shuffle(ctx, &t, 0).unwrap().0;
            let row = rylon::dist::shuffle_rows(ctx, &t).unwrap().0;
            (key, row)
        })
    };
    let serial = run(1);
    for threads in [2usize, 7] {
        let par = run(threads);
        for ((ks, rs), (kp, rp)) in serial.iter().zip(&par) {
            assert!(kp.data_equals(ks), "key shuffle, threads={threads}");
            assert!(rp.data_equals(rs), "row shuffle, threads={threads}");
        }
    }
}
