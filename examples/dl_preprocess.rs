//! Deep-learning pre-processing (§III-A, Fig. 5/6): Rylon as a library
//! inside an AI training job — ETL the features, then hand zero-copy
//! column slices to the "training framework" as f32 tensors.
//!
//! The paper's Fig. 5 does `Table -> Arrow -> pandas -> numpy -> torch
//! tensor`. Here the boundary is the FFI handle layer: the "host
//! framework" sees borrowed column buffers, no copies until tensor
//! materialization itself.
//!
//! ```bash
//! cargo run --release --example dl_preprocess
//! ```

use rylon::api::ffi;
use rylon::coordinator::StreamOrchestrator;
use rylon::io::generator::paper_table;
use rylon::ops::join::JoinConfig;
use rylon::ops::select::select_i64;
use rylon::prelude::*;

/// The "training framework" side: consumes feature batches as flat f32
/// tensors (what a torch DataLoader would wrap).
#[derive(Default)]
struct TensorSink {
    batches: usize,
    values: usize,
    checksum: f64,
}

impl TensorSink {
    /// Materialize a [rows × features] f32 tensor from table columns.
    fn consume(&mut self, t: &Table, feature_cols: &[usize]) {
        let rows = t.num_rows();
        let mut tensor = Vec::with_capacity(rows * feature_cols.len());
        for &c in feature_cols {
            let col = t.column(c).as_f64().expect("feature column is f64");
            // Zero-copy borrow of the column buffer; the cast to f32 is
            // the tensor materialization.
            tensor.extend(col.values().iter().map(|&v| v as f32));
        }
        self.batches += 1;
        self.values += tensor.len();
        self.checksum += tensor.iter().map(|&v| v as f64).sum::<f64>();
    }
}

fn main() -> Result<()> {
    // ---- 1. Feature engineering with the Table API. -----------------
    let samples = paper_table(200_000, 0.7, 11);
    let labels = paper_table(150_000, 0.7, 12);

    // join samples to labels, keep matched ones with key % 5 != 0
    // (a train split), project the 3 feature columns.
    let cfg = JoinConfig::inner(0, 0).with_algorithm(JoinAlgorithm::Hash);
    let joined = rylon::ops::join::join(&samples, &labels, &cfg)?;
    let train = select_i64(&joined, 0, |k| k % 5 != 0)?;
    let features = rylon::ops::project::project(&train, &[1, 2, 3])?;
    println!(
        "[dl] engineered {} training rows × {} features",
        features.num_rows(),
        features.num_columns()
    );

    // ---- 2. Cross the binding boundary as a zero-copy handle. -------
    let handle = ffi::rylon_table_new(features.clone());
    let mut sink = TensorSink::default();
    unsafe {
        let borrowed = ffi::rylon_table_borrow(handle).expect("live handle");
        sink.consume(borrowed, &[0, 1, 2]);
        ffi::rylon_table_free(handle);
    }
    println!(
        "[dl] tensor batch: {} values, checksum {:.3}",
        sink.values, sink.checksum
    );

    // ---- 3. Streaming loader with backpressure (distributed data
    //          loader, §III-A): batches flow source→transform→sink with
    //          a bounded queue. ---------------------------------------
    let mut epoch_sink = TensorSink::default();
    let mut batch_no = 0;
    let stats = StreamOrchestrator::new(4).run(
        move || {
            batch_no += 1;
            (batch_no <= 20).then(|| paper_table(10_000, 0.7, 500 + batch_no as u64))
        },
        |batch| {
            let filtered = select_i64(&batch, 0, |k| k % 5 != 0)?;
            rylon::ops::project::project(&filtered, &[1, 2, 3])
        },
        |features| {
            epoch_sink.consume(&features, &[0, 1, 2]);
            Ok(())
        },
    )?;
    println!(
        "[dl] streamed {} batches / {} rows through the loader in {:.3}s \
         (producer blocked {:.1} ms by backpressure)",
        stats.batches,
        stats.rows,
        stats.elapsed_secs,
        stats.blocked_secs * 1e3
    );
    Ok(())
}
