//! Quickstart: the paper's Fig. 4 program — load CSVs, distributed
//! inner join across workers, write results — in ~40 lines of Rylon.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use rylon::coordinator::try_run_workers;
use rylon::io::csv::{read_csv, write_csv, CsvReadOptions};
use rylon::io::generator::paper_table;
use rylon::net::CommConfig;
use rylon::ops::join::JoinConfig;
use rylon::prelude::*;

fn main() -> Result<()> {
    let dir = std::env::temp_dir().join("rylon_quickstart");
    std::fs::create_dir_all(&dir)?;

    // Generate the paper's benchmark schema (1 int64 key + 3 float64)
    // as two CSV inputs, one partition per worker (Fig. 4 loads
    // "csv1.csv", "csv2.csv" the same way).
    let workers = 4;
    for w in 0..workers {
        write_csv(&paper_table(25_000, 0.9, 100 + w as u64), dir.join(format!("left{w}.csv")))?;
        write_csv(&paper_table(25_000, 0.9, 200 + w as u64), dir.join(format!("right{w}.csv")))?;
    }

    // InitDistributed + DistributedJoin + WriteCSV, per worker.
    let dir2 = dir.clone();
    let results = try_run_workers(workers, &CommConfig::default(), None, move |ctx| {
        let opts = CsvReadOptions::default();
        let rank = ctx.rank();
        let left = read_csv(dir2.join(format!("left{rank}.csv")), &opts)?;
        let right = read_csv(dir2.join(format!("right{rank}.csv")), &opts)?;

        let cfg = JoinConfig::inner(0, 0).with_algorithm(JoinAlgorithm::Hash);
        let (joined, stats) = dist_join(ctx, &left, &right, &cfg)?;

        write_csv(&joined, dir2.join(format!("joined{rank}.csv")))?;
        Ok((joined.num_rows(), stats))
    })?;

    let total: usize = results.iter().map(|(n, _)| n).sum();
    println!("distributed join matched {total} rows across {workers} workers");
    for (w, (n, stats)) in results.iter().enumerate() {
        println!(
            "  worker {w}: {n} rows (partition {:.1} ms, comm {:.1} ms, local {:.1} ms)",
            stats.partition_secs * 1e3,
            stats.comm_secs * 1e3,
            stats.local_secs * 1e3
        );
    }
    println!("outputs in {}", dir.display());
    Ok(())
}
