//! End-to-end ETL driver — the repository's headline validation run.
//!
//! Exercises **all layers composed**: CSV ingest → AOT (JAX/Pallas via
//! PJRT) hash-partition on the shuffle hot path → distributed join →
//! select/project post-processing → distributed union → CSV egress,
//! across W in-process workers, and reports the paper's headline metric
//! (operator wall-clock + Rylon-vs-baseline speedup) on this workload.
//! Recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! make artifacts && cargo run --release --example etl_pipeline
//! ```

use rylon::coordinator::try_run_workers;
use rylon::io::csv::{read_csv, write_csv, CsvReadOptions};
use rylon::io::generator::paper_table;
use rylon::net::{CommConfig, NetworkProfile};
use rylon::ops::join::JoinConfig;
use rylon::ops::select::select_i64;
use rylon::prelude::*;
use rylon::runtime::KernelRuntime;
use rylon::sim::{sim_rowstore_join, BaselineSimConfig};
use std::sync::Arc;
use std::time::Instant;

fn main() -> Result<()> {
    let workers = 8;
    let rows_per_worker = 50_000;
    let dir = std::env::temp_dir().join("rylon_etl");
    std::fs::create_dir_all(&dir)?;

    // ---- Stage 0: land raw CSV data (one shard per worker). --------
    println!("[etl] generating {} rows of raw CSV...", 2 * workers * rows_per_worker);
    for w in 0..workers {
        write_csv(
            &paper_table(rows_per_worker, 0.8, 1000 + w as u64),
            dir.join(format!("orders{w}.csv")),
        )?;
        write_csv(
            &paper_table(rows_per_worker, 0.8, 2000 + w as u64),
            dir.join(format!("payments{w}.csv")),
        )?;
    }

    // ---- AOT kernel runtime (Pallas hash on the hot path). ---------
    let runtime = match KernelRuntime::load_default() {
        Ok(rt) => {
            println!("[etl] AOT kernel runtime loaded, blocks {:?}", rt.block_sizes());
            Some(Arc::new(rt))
        }
        Err(e) => {
            println!("[etl] AOT runtime unavailable ({e}); native hash fallback");
            None
        }
    };

    // ---- Distributed pipeline across workers. ----------------------
    let config = CommConfig::default().with_profile(NetworkProfile::Loopback);
    let dir2 = dir.clone();
    let t0 = Instant::now();
    let results = try_run_workers(workers, &config, runtime.clone(), move |ctx| {
        let opts = CsvReadOptions::default();
        let rank = ctx.rank();
        let orders = read_csv(dir2.join(format!("orders{rank}.csv")), &opts)?;
        let payments = read_csv(dir2.join(format!("payments{rank}.csv")), &opts)?;

        // 1. Distributed join orders ⨝ payments on the key column —
        //    the shuffle's partition ids come from the PJRT artifact.
        let cfg = JoinConfig::inner(0, 0).with_algorithm(JoinAlgorithm::Hash);
        let (joined, jstats) = dist_join(ctx, &orders, &payments, &cfg)?;

        // 2. Select: keep rows with even key (pleasingly parallel).
        let filtered = select_i64(&joined, 0, |k| k % 2 == 0)?;

        // 3. Project: key + the two primary value columns.
        let view = rylon::ops::project::project(&filtered, &[0, 1, 5])?;

        // 4. Distributed union with itself dedups shuffled duplicates
        //    (exercises the row-hash shuffle path).
        let (distinct, ustats) = dist_union(ctx, &view, &view)?;

        write_csv(&distinct, dir2.join(format!("curated{rank}.csv")))?;
        Ok((joined.num_rows(), distinct.num_rows(), jstats, ustats))
    })?;
    let wall = t0.elapsed().as_secs_f64();

    let joined: usize = results.iter().map(|r| r.0).sum();
    let curated: usize = results.iter().map(|r| r.1).sum();
    let jagg =
        rylon::dist::OpStats::bsp_max(&results.iter().map(|r| r.2).collect::<Vec<_>>());
    println!("[etl] joined {joined} rows, curated {curated} distinct rows");
    println!(
        "[etl] pipeline wall {wall:.3}s; join breakdown: partition {:.3}s, comm {:.3}s, local {:.3}s",
        jagg.partition_secs, jagg.comm_secs, jagg.local_secs
    );
    if let Some(rt) = &runtime {
        let s = rt.stats().map_err(|e| rylon::error::Error::runtime(e.to_string()))?;
        println!(
            "[etl] AOT kernel: {} calls, {} rows hashed, {:.3}s in PJRT",
            s.kernel_calls, s.rows_hashed, s.kernel_secs
        );
    }

    // ---- Headline metric: Rylon vs the Spark-like baseline. --------
    let lchunks: Vec<Table> = (0..workers)
        .map(|w| paper_table(rows_per_worker, 0.8, 1000 + w as u64))
        .collect();
    let rchunks: Vec<Table> = (0..workers)
        .map(|w| paper_table(rows_per_worker, 0.8, 2000 + w as u64))
        .collect();
    let cfg = JoinConfig::inner(0, 0);
    let ry = rylon::sim::sim_rylon_join(
        &lchunks,
        &rchunks,
        &cfg,
        NetworkProfile::Infiniband40G,
        runtime.as_ref(),
    )?;
    let sp = sim_rowstore_join(
        &lchunks,
        &rchunks,
        0,
        0,
        &BaselineSimConfig::default(),
    )?;
    println!(
        "[etl] headline (BSP virtual clock, W={workers}): join {:.3}s vs spark-like {:.3}s \
         => {:.1}x speedup (paper Table II: 4.1x–7.8x)",
        ry.virtual_secs,
        sp.virtual_secs,
        sp.virtual_secs / ry.virtual_secs
    );
    println!("[etl] outputs in {}", dir.display());
    Ok(())
}
