//! Dataflow + out-of-core analytics — the paper's §VI future work,
//! exercised end-to-end:
//!
//! 1. a **dataflow graph** (declarative DAG) running distributed:
//!    join → derived column → filter → group-by, on 4 workers;
//! 2. the same aggregation answered **out-of-core**: external
//!    (spill-to-disk) sort and Grace hash join with a tiny memory
//!    budget, verified against the in-memory result.
//!
//! ```bash
//! cargo run --release --example dataflow_analytics
//! ```

use rylon::coordinator::run_workers;
use rylon::dataflow::Graph;
use rylon::external::{external_join, external_sort};
use rylon::io::generator::paper_table;
use rylon::net::CommConfig;
use rylon::ops::aggregate::{AggFn, AggSpec};
use rylon::ops::expr::Expr;
use rylon::ops::join::JoinConfig;
use rylon::prelude::*;

fn build_graph() -> Graph {
    let mut g = Graph::new();
    let orders = g.source("orders");
    let refunds = g.source("refunds");
    // revenue = c1 * 100; keep revenue > 25; total per key
    let j = g.join(orders, refunds, JoinConfig::inner(0, 0));
    let rev = g.with_column(j, "revenue", Expr::col(1).mul(Expr::lit_f64(100.0)));
    let hot = g.filter(rev, Expr::col(8).gt(Expr::lit_f64(25.0)));
    let agg = g.group_by(
        hot,
        0,
        vec![AggSpec::new(AggFn::Sum, 8), AggSpec::new(AggFn::Count, 8)],
    );
    g.sink(agg);
    g
}

fn main() -> Result<()> {
    // ---- 1. Declarative distributed dataflow. ----------------------
    let g = build_graph();
    println!("[dataflow] plan:\n{}", g.explain());
    let world = 4;
    // What the query planner does to this graph at world 4: the unused
    // join payload columns never cross the wire, and the group-by's
    // partial shuffle is elided (its input is already hash-partitioned
    // on the key by the distributed join).
    let preview = [
        ("orders", paper_table(64, 0.3, 1)),
        ("refunds", paper_table(64, 0.3, 2)),
    ];
    println!("[planner]\n{}", g.explain_optimized(world, &preview)?);
    let outs = run_workers(world, &CommConfig::default(), move |ctx| {
        let orders = paper_table(40_000, 0.3, 3000 + ctx.rank() as u64);
        let refunds = paper_table(10_000, 0.3, 4000 + ctx.rank() as u64);
        let (mut tables, stats) = build_graph()
            .execute_with_stats(ctx, &[("orders", orders), ("refunds", refunds)])
            .unwrap();
        (tables.remove(0), stats)
    });
    let groups: usize = outs.iter().map(|(t, _)| t.num_rows()).sum();
    let elided: usize = outs[0].1.shuffles_elided;
    println!("[dataflow] distributed group-by produced {groups} key groups across {world} workers");
    println!("[dataflow] planner elided {elided} AllToAll shuffle(s) per worker");

    // ---- 2. Out-of-core: same join, 4k-row memory budget. ----------
    let big_l = paper_table(200_000, 0.5, 61);
    let big_r = paper_table(200_000, 0.5, 62);
    let cfg = JoinConfig::inner(0, 0);
    let t0 = std::time::Instant::now();
    let in_mem = rylon::ops::join::join(&big_l, &big_r, &cfg)?;
    let t_mem = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let external = external_join(&big_l, &big_r, &cfg, 4_096)?;
    let t_ext = t1.elapsed().as_secs_f64();
    assert_eq!(in_mem.num_rows(), external.num_rows());
    println!(
        "[external] Grace join of 2×200k rows under a 4k-row budget: \
         {} rows, {:.2}s (in-memory {:.2}s, {:.1}x overhead for spilling)",
        external.num_rows(),
        t_ext,
        t_mem,
        t_ext / t_mem
    );

    let t2 = std::time::Instant::now();
    let sorted = external_sort(&big_l, 0, 8_192)?;
    println!(
        "[external] spill-sort of 200k rows under an 8k-row budget: {:.2}s, sorted={}",
        t2.elapsed().as_secs_f64(),
        rylon::ops::sort::is_sorted(&sorted, 0)
    );
    Ok(())
}
