//! Framework mode (§III-B): Rylon as a standalone distributed engine —
//! the coordinator brings up workers, distributes all four relational
//! set/join operators over a partitioned dataset, aggregates metrics at
//! the leader via collectives, and tears down.
//!
//! ```bash
//! cargo run --release --example framework_mode
//! ```

use rylon::coordinator::try_run_workers;
use rylon::io::generator::worker_partition;
use rylon::net::{CommConfig, NetworkProfile};
use rylon::ops::join::JoinConfig;
use rylon::prelude::*;

fn main() -> Result<()> {
    let world = 6;
    let total_rows = 120_000;
    println!("[framework] leader bringing up {world} workers (mpirun analog)...");

    let config = CommConfig::default().with_profile(NetworkProfile::Loopback);
    let results = try_run_workers(world, &config, None, move |ctx| {
        let rank = ctx.rank();
        // Each worker owns its partition (paper: each process holds a
        // partition "as if they are working on the entire dataset").
        let a = worker_partition(total_rows, ctx.world(), rank, 0.6, 77);
        let b = worker_partition(total_rows, ctx.world(), rank, 0.6, 88);

        let (joined, _) = dist_join(ctx, &a, &b, &JoinConfig::inner(0, 0))?;
        let (union_t, _) = dist_union(ctx, &a, &b)?;
        let (inter_t, _) = dist_intersect(ctx, &a, &b)?;
        let (diff_t, _) = dist_difference(ctx, &a, &b)?;
        let (sorted, _) = dist_sort(ctx, &a, 0)?;

        // Leader-side metric aggregation through the collective layer.
        let global_join = ctx.communicator().all_reduce_sum_u64(joined.num_rows() as u64)?;
        let global_union = ctx.communicator().all_reduce_sum_u64(union_t.num_rows() as u64)?;
        let global_inter = ctx.communicator().all_reduce_sum_u64(inter_t.num_rows() as u64)?;
        let global_diff = ctx.communicator().all_reduce_sum_u64(diff_t.num_rows() as u64)?;
        ctx.communicator().barrier()?;
        Ok((
            rank,
            sorted.num_rows(),
            global_join,
            global_union,
            global_inter,
            global_diff,
            ctx.communicator().comm_bytes(),
        ))
    })?;

    let (_, _, join_rows, union_rows, inter_rows, diff_rows, _) = results[0];
    println!("[framework] global results (identical on every worker):");
    println!("  distributed join      : {join_rows} rows");
    println!("  distributed union     : {union_rows} rows");
    println!("  distributed intersect : {inter_rows} rows");
    println!("  distributed difference: {diff_rows} rows");
    // union = intersect + symmetric difference, globally.
    assert_eq!(union_rows, inter_rows + diff_rows);
    println!("  invariant |A∪B| = |A∩B| + |AΔB| holds globally ✓");
    for (rank, sorted_rows, .., bytes) in &results {
        println!("  worker {rank}: sorted run {sorted_rows} rows, {bytes} wire bytes");
    }
    println!("[framework] leader tearing down; all workers finalized");
    Ok(())
}
